//! Tables 2, 3, S.4, S.5 and Figure S.13 — benchmark-model compression.
//!
//! The zoo layers are truncated to `--weights` weights each (whole rows)
//! — `E` and memory reduction are bit-ratio statistics that converge with
//! a few 10⁴–10⁵ bits; EXPERIMENTS.md records convergence evidence.

use super::ExpOptions;
use crate::cli::Args;
use crate::container::Dtype;
use crate::models::{
    resnet50_layers, transformer_layers, LayerSpec, SyntheticLayer,
    WeightGen,
};
use crate::pipeline::{CompressionConfig, Compressor, LayerReport};
use crate::pruning::{MaskStats, PruneMethod, Pruner};
use crate::report::{fmt_pct, fmt_ratio, Table};
use crate::repro::fig4::print_table;
use anyhow::Result;

/// Representative layer subset per model (documented substitution: the
/// paper compresses every layer; we sample a spread of shapes).
fn transformer_subset() -> Vec<LayerSpec> {
    let all = transformer_layers();
    ["enc0/self_att/q", "enc3/ffn1", "dec3/self_att/q", "dec3/ffn2"]
        .iter()
        .map(|n| all.iter().find(|l| &l.name == n).unwrap().clone())
        .collect()
}

fn resnet_subset() -> Vec<LayerSpec> {
    let all = resnet50_layers();
    [
        "group2_layer3_conv1",
        "group3_layer3_conv2",
        "group4_layer0_downsample",
        "fc",
    ]
    .iter()
    .map(|n| all.iter().find(|l| &l.name == n).unwrap().clone())
    .collect()
}

fn gen_layers(
    specs: &[LayerSpec],
    max_weights: usize,
    seed: u64,
) -> Vec<SyntheticLayer> {
    specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            SyntheticLayer::generate(s, WeightGen::default(), seed ^ i as u64)
                .truncated(max_weights)
        })
        .collect()
}

fn compress_agg(
    layers: &[SyntheticLayer],
    dtype: Dtype,
    cfg: CompressionConfig,
) -> LayerReport {
    let c = Compressor::new(cfg);
    let (_, reports) = c.compress_model(layers, dtype);
    LayerReport::aggregate("agg", &reports)
}

/// Table 2: E% and memory reduction for sparse Transformer and ResNet-50,
/// FP32 + INT8, S ∈ {70%, 90%}, {Magnitude, Random} pruning,
/// N_s ∈ {0(±inv), 1(±inv), 2}. Expected shape: E and memory reduction
/// rise with N_s; inverting helps FP32 at low N_s and is a no-op for
/// INT8; random ≈ magnitude.
pub fn table2(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 0)?;
    let max_w: usize = args.get("weights", 4096)?;
    let beam = opt.beam.or(Some(8));

    let mut table = Table::new(
        &format!(
            "Table 2 (sampled layers, {} weights each; beam={:?} for N_s=2)",
            max_w, beam
        ),
        &[
            "Model", "S(Method)", "E ns0(inv)", "E ns1(inv)", "E ns2",
            "MR ns0(inv)", "MR ns1(inv)", "MR ns2",
        ],
    );

    let rows: Vec<(&str, Dtype, Vec<LayerSpec>)> = vec![
        ("Transformer FP32", Dtype::F32, transformer_subset()),
        ("ResNet-50 FP32", Dtype::F32, resnet_subset()),
        ("ResNet-50 INT8", Dtype::I8, resnet_subset()),
    ];

    for (model, dtype, specs) in rows {
        let layers = gen_layers(&specs, max_w, opt.seed);
        for &s in &[0.7, 0.9] {
            for method in [PruneMethod::Magnitude, PruneMethod::Random] {
                let run = |n_s: usize, invert: bool| -> LayerReport {
                    compress_agg(
                        &layers,
                        dtype,
                        CompressionConfig {
                            n_in: 8,
                            n_s,
                            sparsity: s,
                            method,
                            invert,
                            seed: opt.seed,
                            beam: if n_s >= 2 { beam } else { None },
                            ..Default::default()
                        },
                    )
                };
                let r0 = run(0, false);
                let r1 = run(1, false);
                let r2 = run(2, false);
                // Inverting: meaningful for FP32 only (Table 2: N/A for
                // INT8 — balanced planes never trigger the flip).
                let (e0i, e1i, m0i, m1i) = if dtype == Dtype::F32 {
                    let r0i = run(0, true);
                    let r1i = run(1, true);
                    (
                        format!("({})", fmt_pct(r0i.efficiency)),
                        format!("({})", fmt_pct(r1i.efficiency)),
                        format!("({})", fmt_pct(r0i.memory_reduction)),
                        format!("({})", fmt_pct(r1i.memory_reduction)),
                    )
                } else {
                    ("(N/A)".into(), "(N/A)".into(), "(N/A)".into(), "(N/A)".into())
                };
                table.row(vec![
                    model.to_string(),
                    format!("{:.0}%({})", s * 100.0, method.label()),
                    format!("{}{}", fmt_pct(r0.efficiency), e0i),
                    format!("{}{}", fmt_pct(r1.efficiency), e1i),
                    fmt_pct(r2.efficiency),
                    format!("{}{}", fmt_pct(r0.memory_reduction), m0i),
                    format!("{}{}", fmt_pct(r1.memory_reduction), m1i),
                    fmt_pct(r2.memory_reduction),
                ]);
            }
        }
    }
    print_table(&table, opt.csv);
    Ok(())
}

/// Shared engine for Table 3 / S.4 / S.5: per-layer coeff-var(`n_u`) and
/// E for `N_s ∈ {0,1,2}`, measured on the sign plane (balanced bits —
/// representative of the paper's aggregate E, see Figure S.13).
fn layer_cv_table(
    title: &str,
    model_layers: Vec<LayerSpec>,
    picks: &[(&str, PruneMethod)],
    sparsities: &[f64],
    opt: &ExpOptions,
    max_w: usize,
) -> Result<()> {
    let beam = opt.beam.or(Some(8));
    let mut table = Table::new(
        title,
        &[
            "(N_in,N_out)", "Layer", "S", "Method", "CoeffVar",
            "E ns0", "E ns1", "E ns2",
        ],
    );
    for &s in sparsities {
        for (layer_name, method) in picks {
            let spec_l = model_layers
                .iter()
                .find(|l| &l.name == layer_name)
                .unwrap_or_else(|| panic!("layer {layer_name}"));
            let name_salt: u64 = layer_name
                .bytes()
                .fold(0xcbf2_9ce4u64, |h, b| {
                    (h ^ b as u64).wrapping_mul(0x0100_0000_01b3)
                });
            let layer = SyntheticLayer::generate(
                spec_l,
                WeightGen::default(),
                opt.seed ^ 0x5A ^ name_salt,
            )
            .truncated(max_w);
            let dspec0 =
                crate::decoder::DecoderSpec::for_sparsity(8, s, 0);
            let pruner = Pruner::new(*method, s, opt.seed ^ 0x77);
            let mask = pruner.mask(&layer.weights, layer.spec.cols);
            let cv = MaskStats::from_mask(&mask, dspec0.n_out).coeff_var;
            let sign_plane = crate::weights::BitPlanes::from_f32(
                &layer.weights,
            )
            .plane(0)
            .clone();
            let mut es = Vec::new();
            for n_s in 0..=2usize {
                let dspec =
                    crate::decoder::DecoderSpec::for_sparsity(8, s, n_s);
                let res = super::encode_with(
                    dspec,
                    opt.seed ^ 0x31,
                    &sign_plane,
                    &mask,
                    if n_s >= 2 { beam } else { None },
                );
                es.push(res.efficiency());
            }
            table.row(vec![
                format!("(8,{})", dspec0.n_out),
                layer_name.to_string(),
                format!("{s:.1}"),
                method.label().to_string(),
                fmt_ratio(cv),
                fmt_pct(es[0]),
                fmt_pct(es[1]),
                fmt_pct(es[2]),
            ]);
        }
    }
    print_table(&table, opt.csv);
    Ok(())
}

/// Table 3: two Transformer layers × {Random, Magnitude, L0} at S = 0.7.
/// Expected: Random has the binomial CV (~0.30) and the highest E;
/// magnitude/L0 are overdispersed with slightly lower E.
pub fn table3(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 0)?;
    let max_w: usize = args.get("weights", 16384)?;
    let picks = [
        ("dec3/self_att/q", PruneMethod::Random),
        ("dec3/ffn2", PruneMethod::Random),
        ("dec3/self_att/q", PruneMethod::Magnitude),
        ("dec3/ffn2", PruneMethod::Magnitude),
        ("dec3/self_att/q", PruneMethod::L0Reg),
        ("dec3/ffn2", PruneMethod::L0Reg),
    ];
    layer_cv_table(
        "Table 3: coeff-var(n_u) vs E, Transformer, S=0.7",
        transformer_layers(),
        &picks,
        &[0.7],
        &opt,
        max_w,
    )
}

/// Table S.4: Transformer layers, 4 pruning methods, S ∈ {0.7, 0.9}.
pub fn s4(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 0)?;
    let max_w: usize = args.get("weights", 16384)?;
    let picks = [
        ("dec3/self_att/q", PruneMethod::Random),
        ("dec3/ffn2", PruneMethod::Random),
        ("dec3/self_att/q", PruneMethod::Magnitude),
        ("dec3/ffn2", PruneMethod::Magnitude),
        ("dec3/self_att/q", PruneMethod::L0Reg),
        ("dec3/ffn2", PruneMethod::L0Reg),
        ("dec5/self_att/k", PruneMethod::VarDropout),
        ("dec1/ffn1", PruneMethod::VarDropout),
    ];
    layer_cv_table(
        "Table S.4: Transformer per-layer coeff-var and E",
        transformer_layers(),
        &picks,
        &[0.7, 0.9],
        &opt,
        max_w,
    )
}

/// Table S.5: ResNet-50 layers, 3 pruning methods, S ∈ {0.7, 0.9}.
pub fn s5(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 0)?;
    let max_w: usize = args.get("weights", 16384)?;
    let picks = [
        ("group2_layer3_conv1", PruneMethod::Random),
        ("group3_layer5_conv3", PruneMethod::Random),
        ("group2_layer3_conv1", PruneMethod::Magnitude),
        ("group3_layer5_conv3", PruneMethod::Magnitude),
        ("group2_layer3_conv1", PruneMethod::VarDropout),
        ("group3_layer5_conv3", PruneMethod::VarDropout),
    ];
    layer_cv_table(
        "Table S.5: ResNet-50 per-layer coeff-var and E",
        resnet50_layers(),
        &picks,
        &[0.7, 0.9],
        &opt,
        max_w,
    )
}

/// Figure S.13: per-bit-index E (S = 0.7) with and without inverting,
/// for the synthetic Transformer FP32. Expected: inverting lifts the
/// skewed exponent planes at N_s ∈ {0, 1}; negligible at N_s = 2.
pub fn s13(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 0)?;
    let max_w: usize = args.get("weights", 4096)?;
    let beam = opt.beam.or(Some(8));
    let specs = transformer_subset();
    let layers = gen_layers(&specs[..1], max_w, opt.seed);

    let run = |n_s: usize, invert: bool| -> Vec<f64> {
        let rep = compress_agg(
            &layers,
            Dtype::F32,
            CompressionConfig {
                n_in: 8,
                n_s,
                sparsity: 0.7,
                method: PruneMethod::Magnitude,
                invert,
                seed: opt.seed,
                beam: if n_s >= 2 { beam } else { None },
                ..Default::default()
            },
        );
        // aggregate() drops per-plane numbers; recompute from single
        // layer: compress directly.
        let c = Compressor::new(CompressionConfig {
            n_in: 8,
            n_s,
            sparsity: 0.7,
            method: PruneMethod::Magnitude,
            invert,
            seed: opt.seed,
            beam: if n_s >= 2 { beam } else { None },
            ..Default::default()
        });
        let (_, r) = c.compress_layer(&layers[0], Dtype::F32);
        let _ = rep;
        r.per_plane_efficiency
    };

    let e0 = run(0, false);
    let e0i = run(0, true);
    let e1 = run(1, false);
    let e1i = run(1, true);
    let e2 = run(2, false);

    let mut table = Table::new(
        "Figure S.13: per-bit-index E% (Transformer FP32, S=0.7, Mag.)",
        &["bit", "ns0", "ns0+inv", "ns1", "ns1+inv", "ns2"],
    );
    for k in 0..32 {
        table.row(vec![
            k.to_string(),
            fmt_pct(e0[k]),
            fmt_pct(e0i[k]),
            fmt_pct(e1[k]),
            fmt_pct(e1i[k]),
            fmt_pct(e2[k]),
        ]);
    }
    print_table(&table, opt.csv);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subsets_resolve() {
        assert_eq!(transformer_subset().len(), 4);
        assert_eq!(resnet_subset().len(), 4);
    }

    /// The inverting technique must help the skewed FP32 exponent planes
    /// at N_s = 0 (Table 2's "(Inv.)" columns are higher).
    #[test]
    fn inverting_helps_fp32_at_ns0() {
        let opt_seed = 9;
        let specs = transformer_subset();
        let layers = gen_layers(&specs[..1], 2048, opt_seed);
        let run = |invert: bool| {
            compress_agg(
                &layers,
                Dtype::F32,
                CompressionConfig {
                    n_s: 0,
                    sparsity: 0.7,
                    method: PruneMethod::Magnitude,
                    invert,
                    seed: opt_seed,
                    ..Default::default()
                },
            )
        };
        let plain = run(false);
        let inv = run(true);
        assert!(
            inv.efficiency > plain.efficiency,
            "inv {} ≤ plain {}",
            inv.efficiency,
            plain.efficiency
        );
    }
}

//! Figure 1, Figure S.10, Figure S.12, Appendix D — substrate studies.

use super::ExpOptions;
use crate::bandwidth::MemoryModel;
use crate::cli::Args;
use crate::container::Dtype;
use crate::models::{
    resnet50_layers, transformer_layers, SyntheticLayer, WeightGen,
};
use crate::pruning::{MaskStats, PruneMethod, Pruner};
use crate::report::{fmt_pct, fmt_ratio, Table};
use crate::repro::fig4::print_table;
use crate::rng::Rng;
use crate::sparse::{gemm, CsrMatrix, DenseMatrix};
use anyhow::Result;
use std::time::Instant;

/// Figure 1(a) / Appendix A: memory-bandwidth utilization vs sparsity
/// for fixed-to-variable (CSR) vs fixed-to-fixed. Expected: F2F flat at
/// ~100%; CSR decays as S grows; CV of record length (Eq. 5) rises.
pub fn fig1(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 0)?;
    let mm = MemoryModel::default();
    let mut rng = Rng::new(opt.seed);
    let (rows, cols) = (2048usize, 256usize);
    let mut table = Table::new(
        "Figure 1 / Appendix A: bandwidth utilization (64B bursts, 2048x256 layer)",
        &["S", "CSR util%", "F2F util%", "CV(record len)", "CSR xfer/F2F xfer"],
    );
    for &s in &[0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 0.99] {
        let row_nnz: Vec<usize> = (0..rows)
            .map(|_| (0..cols).filter(|_| rng.bernoulli(1.0 - s)).count())
            .collect();
        let (csr, f2f) = mm.compare(&row_nnz, rows * cols, 4, 1.0 - s);
        let lens: Vec<f64> =
            row_nnz.iter().map(|&n| (n * 8) as f64).collect();
        let (mean, sd) = crate::report::mean_sd(&lens);
        table.row(vec![
            format!("{s:.2}"),
            fmt_pct(csr.utilization() * 100.0),
            fmt_pct(f2f.utilization() * 100.0),
            fmt_ratio(if mean > 0.0 { sd / mean } else { 0.0 }),
            fmt_ratio(
                csr.transferred_bytes as f64
                    / f2f.transferred_bytes.max(1) as f64,
            ),
        ]);
    }
    print_table(&table, opt.csv);
    Ok(())
}

/// Figure S.10: normalized execution time of `(N×N sparse) × (N×k dense)`
/// in CSR vs the dense GEMM baseline. Expected shape: CSR beats dense
/// only at high sparsity, and the advantage shrinks as `k` grows;
/// at moderate sparsity CSR is *slower* than dense.
pub fn s10(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 0)?;
    let n: usize = args.get("n", 1024)?;
    let mut rng = Rng::new(opt.seed);
    let mut table = Table::new(
        &format!(
            "Figure S.10: CSR SpMM time / dense GEMM time ({n}x{n} matrix)"
        ),
        &["S", "k=1", "k=4", "k=8", "k=16", "k=32"],
    );
    for &s in &[0.5, 0.7, 0.9, 0.95] {
        let a = DenseMatrix::random_sparse(n, n, s, &mut rng);
        let csr = CsrMatrix::from_dense(&a);
        let mut cells = vec![format!("{s:.2}")];
        for &k in &[1usize, 4, 8, 16, 32] {
            let b = DenseMatrix::random_sparse(n, k, 0.0, &mut rng);
            let reps = if n <= 512 { 3 } else { 1 };
            let td = time_min(reps, || {
                crate::bench_util::black_box(gemm(&a, &b));
            });
            let ts = time_min(reps, || {
                crate::bench_util::black_box(csr.spmm(&b));
            });
            cells.push(fmt_ratio(ts.as_secs_f64() / td.as_secs_f64()));
        }
        table.row(cells);
    }
    print_table(&table, opt.csv);
    println!("(values < 1.0 mean CSR is faster than dense)");
    Ok(())
}

fn time_min(reps: usize, mut f: impl FnMut()) -> std::time::Duration {
    let mut best = std::time::Duration::MAX;
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        f();
        best = best.min(t.elapsed());
    }
    best
}

/// Figure S.12: ratio of zeros per bit index (k = 0 is the sign bit) for
/// Transformer FP32, ResNet-50 FP32, ResNet-50 INT8 under magnitude
/// pruning at S = 0.7. Expected: sign ~0.5; exponent MSBs strongly
/// skewed; mantissa ~0.5; INT8 planes near-balanced.
pub fn s12(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 0)?;
    let max_w: usize = args.get("weights", 65536)?;
    let sample = |specs: Vec<crate::models::LayerSpec>,
                  dtype: Dtype|
     -> Vec<f64> {
        let spec = &specs[specs.len() / 2];
        let layer =
            SyntheticLayer::generate(spec, WeightGen::default(), opt.seed)
                .truncated(max_w);
        let mask = Pruner::new(PruneMethod::Magnitude, 0.7, opt.seed)
            .mask(&layer.weights, layer.spec.cols);
        match dtype {
            Dtype::F32 => crate::weights::BitPlanes::from_f32(
                &layer.weights,
            )
            .zero_ratios(&mask),
            Dtype::I8 => {
                let (q, _) = crate::models::quantize_i8(&layer.weights);
                crate::weights::BitPlanes::from_i8(&q).zero_ratios(&mask)
            }
        }
    };
    let tf = sample(transformer_layers(), Dtype::F32);
    let rn = sample(resnet50_layers(), Dtype::F32);
    let r8 = sample(resnet50_layers(), Dtype::I8);
    let mut table = Table::new(
        "Figure S.12: zero-ratio per bit index (S=0.7 magnitude masks)",
        &["bit", "Transformer FP32", "ResNet-50 FP32", "ResNet-50 INT8"],
    );
    for k in 0..32 {
        table.row(vec![
            k.to_string(),
            fmt_ratio(tf[k]),
            fmt_ratio(rn[k]),
            if k < 8 { fmt_ratio(r8[k]) } else { "-".into() },
        ]);
    }
    print_table(&table, opt.csv);
    Ok(())
}

/// Appendix D: entropy limits for `n_b = 4` blocks. Expected to match
/// the paper exactly: `n_u = 1` → 2 symbols, H = 1; `n_u = 2` → 5
/// symbols, H ≈ 2.28 (fixed-to-fixed: 3 bits); `n_u = 3` → 8 symbols,
/// H = 3.
pub fn entropy(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 0)?;
    let mut table = Table::new(
        "Appendix D: minimal symbol sets and entropy (n_b = 4)",
        &["n_u", "min symbols", "H (bits)", "f2f bits", "max ratio (n_b/H)"],
    );
    for n_u in 1..=3usize {
        let r = crate::entropy::min_symbol_set(4, n_u);
        table.row(vec![
            n_u.to_string(),
            r.symbols.len().to_string(),
            format!("{:.3}", r.entropy),
            r.f2f_bits.to_string(),
            format!("{:.2}", crate::entropy::max_compression_ratio(4, r.entropy)),
        ]);
    }
    print_table(&table, opt.csv);
    Ok(())
}

/// Coefficient-of-variation helper table shown alongside fig1 (Eq. 3–5).
#[allow(dead_code)]
pub fn eq5_table() -> String {
    let mut table = Table::new(
        "Eq. 5: CV of per-block n_u (binomial)",
        &["N_out", "S=0.5", "S=0.7", "S=0.9"],
    );
    for &n in &[8usize, 26, 80, 2048] {
        table.row(vec![
            n.to_string(),
            fmt_ratio(MaskStats::binomial_cv(n, 0.5)),
            fmt_ratio(MaskStats::binomial_cv(n, 0.7)),
            fmt_ratio(MaskStats::binomial_cv(n, 0.9)),
        ]);
    }
    table.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s10_inner_kernels_agree() {
        // The timing harness compares like for like: outputs must match.
        let mut rng = Rng::new(1);
        let a = DenseMatrix::random_sparse(64, 64, 0.9, &mut rng);
        let b = DenseMatrix::random_sparse(64, 4, 0.0, &mut rng);
        let csr = CsrMatrix::from_dense(&a);
        let y1 = gemm(&a, &b);
        let y2 = csr.spmm(&b);
        for (p, q) in y1.data.iter().zip(&y2.data) {
            assert!((p - q).abs() < 1e-3);
        }
    }

    #[test]
    fn eq5_table_renders() {
        let s = eq5_table();
        assert!(s.contains("2048"));
    }
}

//! Figure 8, Figure 9, Table 1 — sequential encoding on synthetic random
//! streams (`N_in = 8`), plus the beam-vs-exact validation.

use super::ExpOptions;
use crate::cli::Args;
use crate::correction::{compressed_bits_eq7, DEFAULT_P};
use crate::decoder::DecoderSpec;
use crate::gf2::BitVecF2;
use crate::report::{fmt_pct, Table};
use crate::repro::fig4::print_table;
use crate::rng::Rng;
use anyhow::Result;

/// Figure 8: impact of `N_s` with various `N_out` (`N_in = 8, S = 0.9`).
/// Columns: per (N_s, N_out): E%, error bits, memory reduction %.
/// Expected shape: E stays ≈100% for sequential encoders until
/// `N_out ≈ N_in/(1−S) = 80`; memory reduction peaks at `N_out = 80` and
/// is maximized by the largest `N_s` (paper: 89.32% at `N_s = 2`).
pub fn fig8(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 120_000)?;
    let s = 0.9;
    let n_in = 8;
    let mut rng = Rng::new(opt.seed);
    let data = BitVecF2::random(opt.bits, 0.5, &mut rng);
    let mask = super::random_mask(opt.bits, s, &mut rng);

    let mut table = Table::new(
        &format!(
            "Figure 8: N_in=8, S=0.9, {} random bits (paper: 1M)",
            opt.bits
        ),
        &["N_s", "N_out", "E%", "err_bits", "enc_bits", "mem_reduction%"],
    );
    for n_s in 0..=2usize {
        for &n_out in &[16, 24, 32, 40, 48, 56, 64, 72, 80, 88, 96] {
            let spec = DecoderSpec::new(n_in, n_out, n_s);
            let res =
                super::encode_with(spec, opt.seed ^ 0x88, &data, &mask, opt.beam);
            let comp = compressed_bits_eq7(
                opt.bits,
                n_in,
                n_out,
                DEFAULT_P,
                res.stats.error_bits,
            );
            let mr = (1.0 - comp as f64 / opt.bits as f64) * 100.0;
            table.row(vec![
                n_s.to_string(),
                n_out.to_string(),
                fmt_pct(res.efficiency()),
                res.stats.error_bits.to_string(),
                res.stats.encoded_bits.to_string(),
                fmt_pct(mr),
            ]);
        }
    }
    print_table(&table, opt.csv);
    Ok(())
}

/// Table 1: memory reduction (%) vs `S` × `N_s`
/// (`N_in = 8, N_out = ⌊N_in/(1−S)⌋`). Expected: each column rises with
/// `N_s`, approaching `S` (paper: 83.5/88.5/89.3 at S=90%).
pub fn table1(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 120_000)?;
    let mut rng = Rng::new(opt.seed);
    let sparsities = [0.6, 0.7, 0.8, 0.9];
    let mut table = Table::new(
        &format!(
            "Table 1: memory reduction %, {} random bits, N_in=8",
            opt.bits
        ),
        &["N_s", "S=60.0%", "S=70.0%", "S=80.0%", "S=90.0%"],
    );
    for n_s in 0..=2usize {
        let mut cells = vec![n_s.to_string()];
        for &s in &sparsities {
            let spec = DecoderSpec::for_sparsity(8, s, n_s);
            let data = BitVecF2::random(opt.bits, 0.5, &mut rng);
            let mask = super::random_mask(opt.bits, s, &mut rng);
            let res = super::encode_with(
                spec,
                opt.seed ^ (n_s as u64) << 4,
                &data,
                &mask,
                opt.beam,
            );
            let comp = compressed_bits_eq7(
                opt.bits,
                8,
                spec.n_out,
                DEFAULT_P,
                res.stats.error_bits,
            );
            cells.push(fmt_pct((1.0 - comp as f64 / opt.bits as f64) * 100.0));
        }
        table.row(cells);
    }
    print_table(&table, opt.csv);
    Ok(())
}

/// Figure 9: E vs the ratio of zeros among unpruned bits (`N_in = 8`,
/// `S = 0.9`, `N_out = 80`), for `N_s ∈ {0,1,2}`. Expected: E rises as
/// zeros dominate (the all-zero input decodes any all-zero block for
/// free), with the gain largest at `N_s = 0` — motivating the inverting
/// technique.
pub fn fig9(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 60_000)?;
    let mut rng = Rng::new(opt.seed);
    let mut table = Table::new(
        &format!("Figure 9: E% vs zero-ratio (S=0.9, {} bits)", opt.bits),
        &["zero_ratio", "N_s=0", "N_s=1", "N_s=2"],
    );
    for &zr in &[0.5, 0.6, 0.7, 0.8, 0.9] {
        let data = BitVecF2::random(opt.bits, 1.0 - zr, &mut rng);
        let mask = super::random_mask(opt.bits, 0.9, &mut rng);
        let mut cells = vec![format!("{zr:.1}")];
        for n_s in 0..=2usize {
            let spec = DecoderSpec::new(8, 80, n_s);
            let res = super::encode_with(
                spec,
                opt.seed ^ 0x99,
                &data,
                &mask,
                opt.beam,
            );
            cells.push(fmt_pct(res.efficiency()));
        }
        table.row(cells);
    }
    print_table(&table, opt.csv);
    Ok(())
}

/// Validation: beam-pruned DP vs exact DP on matched workloads. Reports
/// the E gap so the beam width used by the big sweeps is evidence-backed
/// (recorded in EXPERIMENTS.md).
pub fn beamcheck(args: &Args) -> Result<()> {
    let opt = ExpOptions::from_args(args, 20_000)?;
    let beams = [1u32, 2, 4, 8, 16];
    let mut rng = Rng::new(opt.seed);
    let mut table = Table::new(
        &format!(
            "Beam validation: N_in=8, N_s=2, {} bits (E% vs exact)",
            opt.bits
        ),
        &["S", "N_out", "E_exact%", "E_b1", "E_b2", "E_b4", "E_b8", "E_b16"],
    );
    for &s in &[0.7, 0.9] {
        let spec = DecoderSpec::for_sparsity(8, s, 2);
        let data = BitVecF2::random(opt.bits, 0.5, &mut rng);
        let mask = super::random_mask(opt.bits, s, &mut rng);
        let exact =
            super::encode_with(spec, opt.seed, &data, &mask, None);
        let mut cells = vec![
            format!("{s:.1}"),
            spec.n_out.to_string(),
            fmt_pct(exact.efficiency()),
        ];
        for &b in &beams {
            let r = super::encode_with(
                spec,
                opt.seed,
                &data,
                &mask,
                Some(b),
            );
            cells.push(fmt_pct(r.efficiency()));
        }
        table.row(cells);
    }
    print_table(&table, opt.csv);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1's qualitative claim on a small budget: memory reduction
    /// increases with N_s at fixed S.
    #[test]
    fn memory_reduction_rises_with_ns() {
        let mut rng = Rng::new(5);
        let bits = 16_000;
        let s = 0.9;
        let data = BitVecF2::random(bits, 0.5, &mut rng);
        let mask = crate::repro::random_mask(bits, s, &mut rng);
        let mut mrs = Vec::new();
        for n_s in 0..=2usize {
            let spec = DecoderSpec::for_sparsity(8, s, n_s);
            let res = crate::repro::encode_with(
                spec,
                11,
                &data,
                &mask,
                Some(8),
            );
            let comp = compressed_bits_eq7(
                bits,
                8,
                spec.n_out,
                DEFAULT_P,
                res.stats.error_bits,
            );
            mrs.push((1.0 - comp as f64 / bits as f64) * 100.0);
        }
        assert!(mrs[1] > mrs[0], "{mrs:?}");
        assert!(mrs[2] >= mrs[1] - 0.5, "{mrs:?}");
        // And the best approaches S = 90%.
        assert!(mrs[2] > 80.0, "{mrs:?}");
    }

    /// Figure 9's claim: more zeros ⇒ higher E at N_s = 0.
    #[test]
    fn zero_skew_helps_ns0() {
        let mut rng = Rng::new(6);
        let bits = 24_000;
        let spec = DecoderSpec::new(8, 80, 0);
        let mask = crate::repro::random_mask(bits, 0.9, &mut rng);
        let e_at = |p_one: f64, rng: &mut Rng| {
            let data = BitVecF2::random(bits, p_one, rng);
            crate::repro::encode_with(spec, 3, &data, &mask, None)
                .efficiency()
        };
        let e_balanced = e_at(0.5, &mut rng);
        let e_skewed = e_at(0.1, &mut rng); // 90% zeros
        assert!(
            e_skewed > e_balanced,
            "skewed {e_skewed} vs balanced {e_balanced}"
        );
    }
}

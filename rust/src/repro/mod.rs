//! Reproduction harness: one entry point per table/figure of the paper.
//!
//! Each command prints the same rows/series the paper reports (values
//! differ — our substrate is synthetic, see DESIGN.md §2 — but the
//! *shape* must hold: who wins, by what factor, where the crossovers
//! fall). `f2f repro <id> [--bits N] [--seed N] [--trials N] [--beam W]
//! [--csv]`.
//!
//! Workload sizes default to CPU-friendly values; EXPERIMENTS.md records
//! the sizes used for the checked-in runs. The `--beam` option switches
//! the `N_s = 2` cells to beam-pruned DP (validated against exact DP in
//! `f2f repro beamcheck`).

mod appendix;
mod fig4;
mod fig8;
mod tables;

use crate::cli::Args;
use anyhow::{bail, Result};

/// Options shared by every experiment.
#[derive(Debug, Clone, Copy)]
pub struct ExpOptions {
    /// Bits per measured plane/stream.
    pub bits: usize,
    /// Base seed.
    pub seed: u64,
    /// Independent trials (where the paper reports mean ± sd).
    pub trials: usize,
    /// Beam width for `N_s = 2` cells (None = exact DP).
    pub beam: Option<u32>,
    /// Emit CSV instead of the text table.
    pub csv: bool,
}

impl ExpOptions {
    /// Pull the shared options out of parsed args, with per-experiment
    /// default bit budget.
    pub fn from_args(args: &Args, default_bits: usize) -> Result<Self> {
        let beam: i64 = args.get("beam", -1)?;
        Ok(ExpOptions {
            bits: args.get("bits", default_bits)?,
            seed: args.get("seed", 0xF2F_2022)?,
            trials: args.get("trials", 10)?,
            beam: if beam < 0 { None } else { Some(beam as u32) },
            csv: args.flag("csv"),
        })
    }
}

/// Dispatch `f2f repro <id>`.
pub fn run(args: &Args) -> Result<()> {
    let id = args.pos(1)?;
    match id {
        "fig1" => appendix::fig1(args),
        "fig4a" => fig4::fig4a(args),
        "fig4b" => fig4::fig4b(args),
        "fig4c" => fig4::fig4c(args),
        "fig8" => fig8::fig8(args),
        "fig9" => fig8::fig9(args),
        "table1" => fig8::table1(args),
        "table2" => tables::table2(args),
        "table3" => tables::table3(args),
        "s4" => tables::s4(args),
        "s5" => tables::s5(args),
        "s10" => appendix::s10(args),
        "s12" => appendix::s12(args),
        "s13" => tables::s13(args),
        "entropy" => appendix::entropy(args),
        "beamcheck" => fig8::beamcheck(args),
        "all" => {
            // Everything at reduced sizes — the CI smoke pass.
            for id in [
                "fig1", "fig4a", "fig4b", "fig4c", "fig8", "fig9",
                "table1", "table2", "table3", "s4", "s5", "s10", "s12",
                "s13", "entropy",
            ] {
                let mut forwarded = vec!["repro".to_string(), id.to_string()];
                forwarded.extend(args.positional.iter().skip(2).cloned());
                let sub = Args::parse(forwarded.into_iter());
                run(&sub)?;
                println!();
            }
            Ok(())
        }
        other => bail!(
            "unknown experiment {other:?}; see DESIGN.md §5 for the list"
        ),
    }
}

// ---------- shared measurement helpers ----------

use crate::decoder::{DecoderSpec, SequentialDecoder};
use crate::encoder::{Encoder, EncodeResult, SlicedPlane, ViterbiEncoder};
use crate::gf2::BitVecF2;
use crate::rng::Rng;

/// Encode a (data, mask) pair with a fresh random decoder; `beam` applies
/// only when `N_s ≥ 2` (exact DP is cheap below that).
pub(crate) fn encode_with(
    spec: DecoderSpec,
    m_seed: u64,
    data: &BitVecF2,
    mask: &BitVecF2,
    beam: Option<u32>,
) -> EncodeResult {
    let dec = SequentialDecoder::random(spec, m_seed);
    let enc = match beam {
        Some(b) if spec.n_s >= 2 => ViterbiEncoder::with_beam(dec, b),
        _ => ViterbiEncoder::new(dec),
    };
    enc.encode(&SlicedPlane::new(data, mask, spec.n_out))
}

/// Bernoulli mask of sparsity `s`.
pub(crate) fn random_mask(bits: usize, s: f64, rng: &mut Rng) -> BitVecF2 {
    BitVecF2::random(bits, 1.0 - s, rng)
}

/// Mask with *exactly* `n_u` unpruned bits per `n_out` block (Fig. 4a's
/// `Var[n_u] = 0` setting).
pub(crate) fn fixed_nu_mask(
    bits: usize,
    n_out: usize,
    n_u: usize,
    rng: &mut Rng,
) -> BitVecF2 {
    let mut mask = BitVecF2::zeros(bits);
    let blocks = bits / n_out;
    let mut perm: Vec<usize> = (0..n_out).collect();
    for t in 0..blocks {
        rng.shuffle(&mut perm);
        for &p in perm.iter().take(n_u) {
            mask.set(t * n_out + p, true);
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_nu_mask_has_exact_counts() {
        let mut rng = Rng::new(1);
        let m = fixed_nu_mask(800, 20, 7, &mut rng);
        for t in 0..40 {
            assert_eq!(m.block(t * 20, 20).count_ones(), 7);
        }
    }

    #[test]
    fn dispatch_rejects_unknown() {
        let args = Args::parse(
            ["repro", "nope"].iter().map(|s| s.to_string()),
        );
        assert!(run(&args).is_err());
    }

    #[test]
    fn encode_with_runs_all_ns() {
        let mut rng = Rng::new(2);
        let data = BitVecF2::random(400, 0.5, &mut rng);
        let mask = random_mask(400, 0.8, &mut rng);
        for n_s in 0..=2 {
            let spec = DecoderSpec::new(4, 20, n_s);
            let r = encode_with(spec, 7, &data, &mask, Some(4));
            assert!(r.stats.unpruned_bits > 0);
        }
    }
}

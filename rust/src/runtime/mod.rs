//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! The build-time Python layer (`python/compile/aot.py`) lowers the JAX
//! decode+matvec model — whose hot spot is the Pallas GF(2) kernel — to
//! **HLO text** (xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos —
//! 64-bit instruction ids; the text parser reassigns ids). This module
//! loads those files, compiles them once on the PJRT CPU client, and
//! executes them from the serving hot path. No Python at request time.
//!
//! The PJRT path needs the external `xla` bindings, which are not part of
//! the offline build. It is therefore gated behind the `pjrt` cargo
//! feature: without it, [`Runtime`] and [`LoadedModel`] are uninhabited
//! stubs whose constructors return a descriptive error, so everything
//! downstream (tests, examples, the serving stack) still compiles and
//! falls back to the native Rust decode path.

/// A typed input tensor for execution.
pub enum Input<'a> {
    F32(&'a [f32], &'a [i64]),
    I32(&'a [i32], &'a [i64]),
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::Input;
    use anyhow::{Context, Result};
    use std::path::Path;

    /// A PJRT client plus the executables loaded into it.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// One compiled model artifact.
    pub struct LoadedModel {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    impl Runtime {
        /// CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .context("creating PJRT CPU client")?;
            Ok(Runtime { client })
        }

        /// Platform string (for logs).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact.
        pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModel> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(LoadedModel {
                exe,
                name: path
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default(),
            })
        }
    }

    impl LoadedModel {
        /// Artifact name (file stem).
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Execute with the given inputs; returns every output tensor as
        /// a flat `f32` vector (the jax side lowers with
        /// `return_tuple=True`).
        pub fn run(&self, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|inp| -> Result<xla::Literal> {
                    Ok(match inp {
                        Input::F32(data, dims) => xla::Literal::vec1(data)
                            .reshape(dims)
                            .context("reshape f32 input")?,
                        Input::I32(data, dims) => xla::Literal::vec1(data)
                            .reshape(dims)
                            .context("reshape i32 input")?,
                    })
                })
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .context("executing PJRT computation")?;
            let first = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            let parts = first.to_tuple().context("untupling result")?;
            parts
                .into_iter()
                .map(|lit| {
                    // Convert whatever numeric type came back to f32.
                    let lit = lit
                        .convert(xla::PrimitiveType::F32)
                        .context("converting output to f32")?;
                    lit.to_vec::<f32>().context("reading output")
                })
                .collect()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{LoadedModel, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::Input;
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Uninhabited stand-in: constructing one always fails, so methods
    /// taking `&self` are statically unreachable.
    pub enum Runtime {}

    /// Uninhabited stand-in for a compiled artifact.
    pub enum LoadedModel {}

    impl Runtime {
        /// Always fails: the crate was built without the `pjrt` feature.
        pub fn cpu() -> Result<Self> {
            bail!(
                "PJRT runtime unavailable: rebuild with `--features pjrt` \
                 (requires the external `xla` bindings)"
            )
        }

        /// Unreachable (no `Runtime` value can exist).
        pub fn platform(&self) -> String {
            match *self {}
        }

        /// Unreachable (no `Runtime` value can exist).
        pub fn load_hlo_text(&self, _path: &Path) -> Result<LoadedModel> {
            match *self {}
        }
    }

    impl LoadedModel {
        /// Unreachable (no `LoadedModel` value can exist).
        pub fn name(&self) -> &str {
            match *self {}
        }

        /// Unreachable (no `LoadedModel` value can exist).
        pub fn run(&self, _inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
            match *self {}
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{LoadedModel, Runtime};

/// True when the real PJRT runtime is compiled in.
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

// Runtime tests that need a real artifact live in
// `rust/tests/runtime_artifacts.rs` (they skip gracefully when
// `artifacts/` hasn't been built). A pure-rust smoke test follows.
#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(!rt.platform().is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_reports_missing_feature() {
        let err = Runtime::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("pjrt"));
        assert!(!pjrt_available());
    }

    #[test]
    fn input_variants_construct() {
        let data = [1.0f32];
        let dims = [1i64];
        let _ = Input::F32(&data, &dims);
        let idata = [1i32];
        let _ = Input::I32(&idata, &dims);
    }
}

//! Appendix G hardware cost model for the XOR-gate decoder.
//!
//! The paper argues the decoder is nearly free in silicon: each 2-input
//! XOR is 6 transistors, all gates fire in one cycle, shift registers add
//! `N_s` cycles of latency but no throughput loss under pipelining. We
//! reproduce that accounting so design-space sweeps can report area and
//! latency alongside compression.

use super::DecoderSpec;
use crate::gf2::XorMatrix;

/// Static cost estimate of one decoder instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HardwareCost {
    /// Exact 2-input XOR gate count (`Σ_i max(taps_i − 1, 0)`).
    pub xor_gates: usize,
    /// Appendix G's closed-form estimate `N_out·(N_s+1)·N_in / 2`.
    pub xor_gates_estimate: usize,
    /// 6 transistors per XOR gate (Rabaey et al. 2004).
    pub transistors: usize,
    /// Flip-flops for the shift registers: `N_s · N_in`.
    pub register_bits: usize,
    /// Decode latency in cycles: 1 (XOR array) + `N_s` (register fill).
    pub latency_cycles: usize,
    /// Output bits produced per cycle once the pipeline is full.
    pub throughput_bits_per_cycle: usize,
}

impl HardwareCost {
    /// Compute the cost of `matrix` under geometry `spec`.
    pub fn of(spec: &DecoderSpec, matrix: &XorMatrix) -> Self {
        let xor_gates = matrix.xor_gate_count();
        let xor_gates_estimate = spec.n_out * spec.total_inputs() / 2;
        HardwareCost {
            xor_gates,
            xor_gates_estimate,
            transistors: 6 * xor_gates,
            register_bits: spec.n_s * spec.n_in,
            latency_cycles: 1 + spec.n_s,
            throughput_bits_per_cycle: spec.n_out,
        }
    }

    /// Transistors per decoded output bit — the paper's "marginal cost"
    /// argument in Appendix G.
    pub fn transistors_per_output_bit(&self) -> f64 {
        self.transistors as f64 / self.throughput_bits_per_cycle as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoder::SequentialDecoder;

    #[test]
    fn cost_of_paper_config() {
        // N_in=8, S=0.9 → N_out=80, N_s=2: App. G estimate
        // N_out·N_in·(N_s+1)/2 = 80·24/2 = 960 gates ≈ 5760 transistors.
        let spec = DecoderSpec::new(8, 80, 2);
        let d = SequentialDecoder::random(spec, 42);
        let c = d.hardware_cost();
        assert_eq!(c.xor_gates_estimate, 960);
        assert_eq!(c.register_bits, 16);
        assert_eq!(c.latency_cycles, 3);
        assert_eq!(c.throughput_bits_per_cycle, 80);
        // Exact count ≈ estimate − N_out (tree of k taps needs k−1 gates).
        let expect = c.xor_gates_estimate as i64 - 80;
        assert!(
            (c.xor_gates as i64 - expect).abs() < 120,
            "exact={} expected≈{}",
            c.xor_gates,
            expect
        );
        assert_eq!(c.transistors, 6 * c.xor_gates);
    }

    #[test]
    fn latency_grows_with_ns_throughput_does_not() {
        let a = SequentialDecoder::random(DecoderSpec::new(8, 40, 0), 1)
            .hardware_cost();
        let b = SequentialDecoder::random(DecoderSpec::new(8, 40, 2), 1)
            .hardware_cost();
        assert!(b.latency_cycles > a.latency_cycles);
        assert_eq!(
            a.throughput_bits_per_cycle,
            b.throughput_bits_per_cycle
        );
    }
}

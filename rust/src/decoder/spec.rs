//! Decoder geometry: `(N_in, N_out, N_s)`.

/// Shape of a sequential XOR-gate decoder.
///
/// * `n_in` — encoded bits consumed per time index (`N_in`; the paper
///   feeds decoders byte-wise, `N_in = 8`, in all §5 experiments).
/// * `n_out` — decoded bits produced per time index (`N_out`). The paper
///   sets `N_out = ⌊N_in / (1−S)⌋` so the code rate matches the pruning
///   rate.
/// * `n_s` — number of shift registers; an input is reused for
///   `N_s + 1` consecutive blocks. `n_s = 0` is the combinational decoder
///   of Kwon et al. (2020).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DecoderSpec {
    pub n_in: usize,
    pub n_out: usize,
    pub n_s: usize,
}

impl DecoderSpec {
    /// Convenience constructor.
    pub fn new(n_in: usize, n_out: usize, n_s: usize) -> Self {
        let s = DecoderSpec { n_in, n_out, n_s };
        s.validate();
        s
    }

    /// Paper's rate rule: `N_out = ⌊N_in · 1/(1−S)⌋` for pruning rate `S`.
    pub fn for_sparsity(n_in: usize, sparsity: f64, n_s: usize) -> Self {
        assert!((0.0..1.0).contains(&sparsity));
        let n_out = ((n_in as f64) / (1.0 - sparsity)).floor() as usize;
        DecoderSpec::new(n_in, n_out, n_s)
    }

    /// Panics if the shape is outside what the implementation supports.
    pub fn validate(&self) {
        assert!(self.n_in >= 1 && self.n_in <= 20, "N_in out of range");
        assert!(self.n_out >= 1 && self.n_out <= 128, "N_out out of range");
        assert!(self.n_s <= 4, "N_s > 4 unsupported (state space 2^(N_in*N_s))");
        assert!(
            self.n_in * (self.n_s + 1) <= 60,
            "total input bits must fit in u64 for decode()"
        );
    }

    /// Total decoder input width `(N_s + 1) · N_in`.
    #[inline]
    pub fn total_inputs(&self) -> usize {
        (self.n_s + 1) * self.n_in
    }

    /// Code rate `N_in / N_out` (compressed fraction before correction).
    pub fn rate(&self) -> f64 {
        self.n_in as f64 / self.n_out as f64
    }

    /// Compression ratio `N_out / N_in` of the raw generator.
    pub fn compression_ratio(&self) -> f64 {
        self.n_out as f64 / self.n_in as f64
    }

    /// Number of blocks for an `n_bits`-bit plane: `l = ⌈n_bits/N_out⌉`.
    pub fn num_blocks(&self, n_bits: usize) -> usize {
        n_bits.div_ceil(self.n_out)
    }

    /// Encoded stream length for `l` blocks (`l + N_s`, Algorithm 3).
    pub fn stream_len(&self, l: usize) -> usize {
        l + self.n_s
    }

    /// Number of Viterbi states `2^{N_in·N_s}`.
    pub fn num_states(&self) -> usize {
        1usize << (self.n_in * self.n_s)
    }

    /// Encoded size in bits for an `n_bits` plane (before correction).
    pub fn encoded_bits(&self, n_bits: usize) -> usize {
        self.stream_len(self.num_blocks(n_bits)) * self.n_in
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_rule_matches_paper() {
        // §5: N_in=8, S=0.9 → N_out = 80.
        let s = DecoderSpec::for_sparsity(8, 0.9, 2);
        assert_eq!(s.n_out, 80);
        // S=0.7 → ⌊8/0.3⌋ = 26.
        let s = DecoderSpec::for_sparsity(8, 0.7, 1);
        assert_eq!(s.n_out, 26);
        // S=0.6 → 20, S=0.8 → 40.
        assert_eq!(DecoderSpec::for_sparsity(8, 0.6, 0).n_out, 20);
        assert_eq!(DecoderSpec::for_sparsity(8, 0.8, 0).n_out, 40);
    }

    #[test]
    fn block_and_stream_accounting() {
        let s = DecoderSpec::new(8, 80, 2);
        assert_eq!(s.num_blocks(1_000_000), 12_500);
        assert_eq!(s.stream_len(12_500), 12_502);
        assert_eq!(s.encoded_bits(1_000_000), 12_502 * 8);
        assert_eq!(s.total_inputs(), 24);
        assert_eq!(s.num_states(), 1 << 16);
    }

    #[test]
    fn partial_tail_block_rounds_up() {
        let s = DecoderSpec::new(4, 10, 0);
        assert_eq!(s.num_blocks(25), 3);
    }

    #[test]
    #[should_panic]
    fn rejects_oversized_state_space() {
        DecoderSpec::new(16, 64, 4).validate(); // 16*5 = 80 input bits > 60
    }
}

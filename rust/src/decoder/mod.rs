//! The XOR-gate decoder, combinational (`N_s = 0`) and sequential.
//!
//! Decoding (Figure 6): encoded vectors stream into the XOR-gate network;
//! `N_s` shift registers keep the previous `N_s` vectors visible, so block
//! `t` is a GF(2)-linear function of the input *sequence*
//! `(w_t^e, w_{t-1}^e, …, w_{t-N_s}^e)`. Registers start at zero (the
//! paper pre-loads `BIN(0)`; Algorithm 3).
//!
//! In hardware this is `N_out·(N_s+1)·N_in/2` XOR gates firing in one
//! cycle; in software we use the [`ChunkTables`] fast path.

mod cost;
mod spec;

pub use cost::HardwareCost;
pub use spec::DecoderSpec;

use crate::gf2::{Block, ChunkTables, XorMatrix};
use crate::kernels::KernelKind;

/// A ready-to-run sequential decoder: spec + matrix + lookup tables.
#[derive(Debug, Clone)]
pub struct SequentialDecoder {
    spec: DecoderSpec,
    matrix: XorMatrix,
    tables: ChunkTables,
}

impl SequentialDecoder {
    /// Build a decoder with a random `M⊕` derived from `seed`.
    pub fn random(spec: DecoderSpec, seed: u64) -> Self {
        let matrix = XorMatrix::random(spec.n_out, spec.total_inputs(), seed);
        let tables = ChunkTables::new(&matrix, spec.n_in, spec.n_s + 1);
        SequentialDecoder { spec, matrix, tables }
    }

    /// Build from an existing matrix (must match the spec's shape).
    pub fn from_matrix(spec: DecoderSpec, matrix: XorMatrix) -> Self {
        assert_eq!(matrix.n_out(), spec.n_out);
        assert_eq!(matrix.n_cols(), spec.total_inputs());
        let tables = ChunkTables::new(&matrix, spec.n_in, spec.n_s + 1);
        SequentialDecoder { spec, matrix, tables }
    }

    /// Decoder geometry.
    #[inline]
    pub fn spec(&self) -> DecoderSpec {
        self.spec
    }

    /// The underlying `M⊕`.
    pub fn matrix(&self) -> &XorMatrix {
        &self.matrix
    }

    /// Chunk tables (used by the encoder's DP inner loop).
    pub fn tables(&self) -> &ChunkTables {
        &self.tables
    }

    /// Decode one block given the current input and register contents.
    /// `history[s]` is the input from `s+1` steps ago; missing history
    /// (start of stream) is zero.
    #[inline]
    pub fn decode_step(&self, current: usize, history: &[usize]) -> Block {
        let mut acc = self.tables.slot(0, current);
        for s in 0..self.spec.n_s {
            let h = history.get(s).copied().unwrap_or(0);
            acc ^= self.tables.slot(s + 1, h);
        }
        acc
    }

    /// Decode a whole stream of encoded vectors into `l` blocks.
    ///
    /// `encoded` has length `l + N_s`: the first `N_s` entries are the
    /// initial register pre-load (all zeros when produced by our encoder,
    /// mirroring Algorithm 3), and entry `t + N_s` is the fresh input for
    /// block `t`.
    pub fn decode_stream(&self, encoded: &[u32]) -> Vec<Block> {
        let ns = self.spec.n_s;
        assert!(
            encoded.len() >= ns,
            "encoded stream shorter than register depth"
        );
        let l = encoded.len() - ns;
        let mut out = Vec::with_capacity(l);
        for t in 0..l {
            // Slot s reads the input from s steps ago = encoded[t + ns - s].
            let mut acc: Block = 0;
            for s in 0..=ns {
                acc ^= self.tables.slot(s, encoded[t + ns - s] as usize);
            }
            out.push(acc);
        }
        out
    }

    /// Decode a stream directly into a flat bit vector of `n_bits` bits
    /// (truncating the final partial block, inverse of slicing).
    /// Dispatches on the active [`KernelKind`]: the default word path
    /// lays each decoded block down with ≤ 3 word ops through
    /// [`crate::kernels::BlockWriter`] instead of `N_out` per-bit
    /// stores.
    pub fn decode_stream_to_bits(
        &self,
        encoded: &[u32],
        n_bits: usize,
    ) -> crate::gf2::BitVecF2 {
        self.decode_stream_to_bits_with(encoded, n_bits, KernelKind::active())
    }

    /// [`SequentialDecoder::decode_stream_to_bits`] with an explicit
    /// kernel choice (benches time scalar vs word through this).
    pub fn decode_stream_to_bits_with(
        &self,
        encoded: &[u32],
        n_bits: usize,
        kind: KernelKind,
    ) -> crate::gf2::BitVecF2 {
        match kind {
            KernelKind::Word => {
                let ns = self.spec.n_s;
                assert!(
                    encoded.len() >= ns,
                    "encoded stream shorter than register depth"
                );
                let l = encoded.len() - ns;
                let mut w = crate::kernels::BlockWriter::new(n_bits);
                for t in 0..l {
                    if w.is_full() {
                        break;
                    }
                    let mut acc: Block = 0;
                    for s in 0..=ns {
                        acc ^= self.tables.slot(s, encoded[t + ns - s] as usize);
                    }
                    w.push(acc, self.spec.n_out);
                }
                w.finish()
            }
            KernelKind::Scalar => {
                let blocks = self.decode_stream(encoded);
                let mut v = crate::gf2::BitVecF2::zeros(n_bits);
                for (t, b) in blocks.iter().enumerate() {
                    let start = t * self.spec.n_out;
                    if start >= n_bits {
                        break;
                    }
                    v.set_block(start, self.spec.n_out.min(n_bits - start), *b);
                }
                v
            }
        }
    }

    /// Hardware cost of this decoder per Appendix G.
    pub fn hardware_cost(&self) -> HardwareCost {
        HardwareCost::of(&self.spec, &self.matrix)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n_in: usize, n_out: usize, n_s: usize) -> DecoderSpec {
        DecoderSpec { n_in, n_out, n_s }
    }

    #[test]
    fn decode_stream_matches_manual_concat() {
        let s = spec(4, 12, 2);
        let d = SequentialDecoder::random(s, 99);
        // encoded stream: 2 preload zeros + 3 inputs
        let encoded = [0u32, 0, 5, 9, 3];
        let blocks = d.decode_stream(&encoded);
        assert_eq!(blocks.len(), 3);
        // Block 0: current=5, history=[0,0]
        let m = d.matrix();
        assert_eq!(blocks[0], m.decode(5));
        // Block 1: current=9, prev=5, prev2=0 → x = 9 | 5<<4
        assert_eq!(blocks[1], m.decode(9 | (5 << 4)));
        // Block 2: current=3, prev=9, prev2=5
        assert_eq!(blocks[2], m.decode(3 | (9 << 4) | (5 << 8)));
    }

    #[test]
    fn nonsequential_decode_is_blockwise() {
        let s = spec(8, 16, 0);
        let d = SequentialDecoder::random(s, 1);
        let encoded = [7u32, 200, 31];
        let blocks = d.decode_stream(&encoded);
        for (i, &e) in encoded.iter().enumerate() {
            assert_eq!(blocks[i], d.matrix().decode(e as u64));
        }
    }

    #[test]
    fn decode_step_equals_stream() {
        let s = spec(6, 20, 1);
        let d = SequentialDecoder::random(s, 2);
        let encoded = [0u32, 11, 45, 60];
        let blocks = d.decode_stream(&encoded);
        assert_eq!(blocks[0], d.decode_step(11, &[0]));
        assert_eq!(blocks[1], d.decode_step(45, &[11]));
        assert_eq!(blocks[2], d.decode_step(60, &[45]));
    }

    #[test]
    fn word_and_scalar_writers_agree() {
        // Sweep n_out (incl. non-divisors of 64) and bit counts with
        // tail words; the two writer kernels must be bit-identical.
        for (n_in, n_out, n_s) in [(4, 10, 0), (6, 12, 2), (8, 64, 1), (5, 96, 0)] {
            let s = spec(n_in, n_out, n_s);
            let d = SequentialDecoder::random(s, 7);
            let encoded: Vec<u32> = (0..40)
                .map(|i| (i * 37 % (1 << n_in)) as u32)
                .collect();
            for n_bits in [1usize, 63, 64, 65, 130, 37 * n_out] {
                let word = d.decode_stream_to_bits_with(
                    &encoded,
                    n_bits,
                    KernelKind::Word,
                );
                let scalar = d.decode_stream_to_bits_with(
                    &encoded,
                    n_bits,
                    KernelKind::Scalar,
                );
                assert_eq!(word, scalar, "n_out={n_out} n_bits={n_bits}");
            }
        }
    }

    #[test]
    fn decode_stream_to_bits_truncates_tail() {
        let s = spec(4, 10, 0);
        let d = SequentialDecoder::random(s, 3);
        let encoded = [1u32, 2, 3];
        let bits = d.decode_stream_to_bits(&encoded, 25); // 2.5 blocks
        assert_eq!(bits.len(), 25);
        let blocks = d.decode_stream(&encoded);
        for i in 0..10 {
            assert_eq!(bits.get(i), (blocks[0] >> i) & 1 == 1);
        }
        for i in 0..5 {
            assert_eq!(bits.get(20 + i), (blocks[2] >> i) & 1 == 1);
        }
    }
}

//! Memory-bandwidth utilization model — Figure 1 and Appendix A.
//!
//! DRAM serves fixed-size bursts. A format whose records have *variable*
//! length (CSR rows after fine-grained pruning) leaves part of many
//! bursts unused and adds data-dependent (pointer-chasing) transactions;
//! a fixed-to-fixed format reads whole bursts of payload back-to-back.
//!
//! We model a memory system with burst size `B` bytes and count, for a
//! workload of per-row records, (a) bytes transferred vs bytes useful and
//! (b) the coefficient of variation of record length (Eq. 3–5), which
//! drives the gap. The simulator reproduces Figure 1(a): fixed-to-fixed
//! sustains flat utilization while CSR utilization decays as sparsity
//! (and with it CV) grows.

use crate::pruning::MaskStats;

/// Burst-granular memory transaction model.
#[derive(Debug, Clone, Copy)]
pub struct MemoryModel {
    /// Burst (minimum transaction) size in bytes.
    pub burst_bytes: usize,
}

impl Default for MemoryModel {
    fn default() -> Self {
        // 64B: one DDR4 BL8 access / one cache line.
        MemoryModel { burst_bytes: 64 }
    }
}

/// Result of simulating one access pattern.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BandwidthReport {
    /// Useful payload bytes the consumer needed.
    pub useful_bytes: usize,
    /// Bytes actually transferred (burst-aligned).
    pub transferred_bytes: usize,
    /// Number of burst transactions issued.
    pub transactions: usize,
}

impl BandwidthReport {
    /// Effective bandwidth utilization `useful / transferred` ∈ (0, 1].
    pub fn utilization(&self) -> f64 {
        if self.transferred_bytes == 0 {
            1.0
        } else {
            self.useful_bytes as f64 / self.transferred_bytes as f64
        }
    }
}

impl MemoryModel {
    /// Fixed-to-variable access: each record is fetched individually
    /// (each compute unit follows its own row pointer, Figure 1(b)), so
    /// every record pays burst rounding.
    pub fn variable_records(&self, record_bytes: &[usize]) -> BandwidthReport {
        let mut useful = 0usize;
        let mut transferred = 0usize;
        let mut transactions = 0usize;
        for &r in record_bytes {
            useful += r;
            let bursts = r.div_ceil(self.burst_bytes).max(1);
            transferred += bursts * self.burst_bytes;
            transactions += bursts;
        }
        BandwidthReport {
            useful_bytes: useful,
            transferred_bytes: transferred,
            transactions,
        }
    }

    /// Fixed-to-fixed access: one contiguous stream of equal-size
    /// records; only the final burst is padded.
    pub fn fixed_stream(&self, total_bytes: usize) -> BandwidthReport {
        let bursts = total_bytes.div_ceil(self.burst_bytes);
        BandwidthReport {
            useful_bytes: total_bytes,
            transferred_bytes: bursts * self.burst_bytes,
            transactions: bursts,
        }
    }

    /// Compare CSR-style vs fixed-to-fixed for a pruned layer:
    /// `row_nnz[i]` unpruned weights per row, `bytes_per_weight` for the
    /// value payload (CSR also pays a 4-byte index per nonzero), and a
    /// fixed-to-fixed rate of `rate = N_in/N_out` compressed bits per bit.
    pub fn compare(
        &self,
        row_nnz: &[usize],
        n_weights: usize,
        bytes_per_weight: usize,
        f2f_rate: f64,
    ) -> (BandwidthReport, BandwidthReport) {
        let records: Vec<usize> = row_nnz
            .iter()
            .map(|&n| n * (bytes_per_weight + 4))
            .collect();
        let csr = self.variable_records(&records);
        let f2f_bytes =
            (n_weights as f64 * bytes_per_weight as f64 * f2f_rate).ceil()
                as usize;
        let f2f = self.fixed_stream(f2f_bytes);
        (csr, f2f)
    }
}

/// Eq. 5 as a standalone helper (re-exported for the Fig. 1 harness).
pub fn csr_coeff_var(n_w: usize, s: f64) -> f64 {
    MaskStats::binomial_cv(n_w, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn fixed_stream_is_nearly_perfect() {
        let m = MemoryModel::default();
        let r = m.fixed_stream(64 * 1000 + 3);
        assert_eq!(r.transactions, 1001);
        assert!(r.utilization() > 0.999);
    }

    #[test]
    fn variable_records_waste_grows_with_fragmentation() {
        let m = MemoryModel::default();
        // 1000 records of 65 bytes: each needs 2 bursts → ~51% utilization.
        let r = m.variable_records(&vec![65; 1000]);
        assert_eq!(r.transactions, 2000);
        assert!((r.utilization() - 65.0 / 128.0).abs() < 1e-9);
    }

    #[test]
    fn short_records_are_the_worst_case() {
        let m = MemoryModel::default();
        // 8-byte records in 64B bursts → 12.5%.
        let r = m.variable_records(&vec![8; 100]);
        assert!((r.utilization() - 0.125).abs() < 1e-9);
    }

    #[test]
    fn f2f_utilization_is_flat_across_sparsity() {
        let m = MemoryModel::default();
        for &s in &[0.5f64, 0.7, 0.9, 0.95] {
            let f2f = m.fixed_stream(
                (2048.0 * 2048.0 * 4.0 * (1.0 - s)) as usize,
            );
            assert!(f2f.utilization() > 0.999, "S={s}");
        }
    }

    #[test]
    fn csr_utilization_decays_with_sparsity() {
        // Figure 1(a): CSR utilization decays as S grows (records shrink
        // toward sub-burst sizes).
        let m = MemoryModel::default();
        let mut rng = Rng::new(1);
        let rows = 2048usize;
        let cols = 256usize; // short rows: the regime Figure 1 depicts
        let mut utils = Vec::new();
        for &s in &[0.5f64, 0.8, 0.95, 0.99] {
            let row_nnz: Vec<usize> = (0..rows)
                .map(|_| {
                    (0..cols).filter(|_| rng.bernoulli(1.0 - s)).count()
                })
                .collect();
            let (csr, _) = m.compare(&row_nnz, rows * cols, 4, 1.0 - s);
            utils.push(csr.utilization());
        }
        for w in utils.windows(2) {
            assert!(
                w[1] <= w[0] + 1e-9,
                "utilization should decay: {utils:?}"
            );
        }
        assert!(utils.last().unwrap() < &0.7);
    }

    #[test]
    fn eq5_helper_matches_maskstats() {
        assert!(
            (csr_coeff_var(2048, 0.9)
                - (0.9f64 / (2048.0 * 0.1)).sqrt())
            .abs()
                < 1e-12
        );
    }
}

//! Observed-cost shard rebalancing: split where the decode time is.
//!
//! [`crate::container::ShardAssignment::ByBytes`] balances *compressed
//! record bytes* at split time — a proxy that ignores how decode cost
//! actually varies with mask density, plane count and correction
//! length (the same per-layer asymmetry the paper's hardware decoder
//! pays in XOR-network depth). A shard holding small-but-expensive
//! records becomes the straggler every cold pass. The fix is to
//! rebalance on *measured* cost:
//!
//! 1. Serve traffic; every store's [`crate::store::LayerCosts`] table
//!    fills with EWMA decode times stamped at the source.
//! 2. Export the merged table as a [`CostProfile`] — flat JSON via
//!    [`crate::bench_util::JsonReport`], the same machine-readable
//!    shape the benches emit (`f2f serve --profile-out`, or
//!    [`CostProfile::to_json`] from code).
//! 3. [`rebalance_map`] greedily re-partitions the container on the
//!    profile's observed per-layer decode cost and emits a validated
//!    `F2F3` [`ShardMap`]; `f2f rebalance` wires it to disk through
//!    [`crate::container::split_with_map`].
//!
//! A profile that does not match the container — missing layers, extra
//! layers, no decode observations, non-finite numbers — is *stale* and
//! rejected as an error, never a panic: rebalancing with last month's
//! model must fail loudly, not ship a skewed partition.

use crate::bench_util::JsonReport;
use crate::container::{ContainerIndex, ShardMap};
use crate::store::{LayerCost, LayerCosts};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A serializable snapshot of per-layer observed costs: the wire form
/// of [`LayerCosts`] tables, merged across stores/shards and carried
/// between processes as flat JSON.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostProfile {
    entries: BTreeMap<String, LayerCost>,
}

impl CostProfile {
    /// An empty profile.
    pub fn new() -> Self {
        CostProfile::default()
    }

    /// Snapshot and merge one or more live cost tables (one per shard
    /// store) into a single model-wide profile.
    pub fn from_stores<'a, I>(tables: I) -> Self
    where
        I: IntoIterator<Item = &'a LayerCosts>,
    {
        let mut profile = CostProfile::new();
        for table in tables {
            for (name, cost) in table.snapshot() {
                profile.record(&name, cost);
            }
        }
        profile
    }

    /// Fold one layer's cost into the profile (sample-weighted merge on
    /// collision).
    pub fn record(&mut self, name: &str, cost: LayerCost) {
        self.entries.entry(name.to_string()).or_default().merge(&cost);
    }

    /// This layer's observed cost, if present.
    pub fn get(&self, name: &str) -> Option<LayerCost> {
        self.entries.get(name).copied()
    }

    /// Number of layers in the profile.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no layer has been recorded.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Name-ordered `(layer, cost)` pairs — the shape
    /// [`crate::store::ModelStore::seed_costs`] accepts.
    pub fn entries(&self) -> Vec<(String, LayerCost)> {
        self.entries
            .iter()
            .map(|(n, c)| (n.clone(), *c))
            .collect()
    }

    /// Predicted total decode ns per shard if this profile served
    /// under `map` — the quantity [`rebalance_map`] balances.
    pub fn shard_loads(&self, map: &ShardMap) -> Vec<f64> {
        let mut loads = vec![0.0f64; map.n_shards()];
        for (name, shard) in map.assignments() {
            if let Some(c) = self.entries.get(name) {
                if let Some(l) = loads.get_mut(*shard) {
                    *l += c.decode_ns;
                }
            }
        }
        loads
    }

    /// Serialize as flat JSON (via [`JsonReport`], the same
    /// machine-readable shape the benches emit): one case per layer
    /// with `decode_ns` / `decode_samples` / `gemv_ns` /
    /// `gemv_samples` metrics.
    pub fn to_json(&self) -> String {
        let mut rep = JsonReport::new("f2f cost profile");
        for (name, c) in &self.entries {
            rep.metric(name, "decode_ns", c.decode_ns);
            rep.metric(name, "decode_samples", c.decode_samples as f64);
            rep.metric(name, "gemv_ns", c.gemv_ns);
            rep.metric(name, "gemv_samples", c.gemv_samples as f64);
        }
        rep.to_json()
    }

    /// Parse a serialized profile. Accepts exactly the flat
    /// `{"title": …, "cases": {layer: {metric: number}}}` shape
    /// [`CostProfile::to_json`] writes (unknown metric keys are
    /// ignored for forward compatibility); anything else is an error,
    /// never a panic.
    pub fn parse_json(s: &str) -> Result<Self> {
        let root = match json::parse(s)? {
            json::Value::Object(fields) => fields,
            _ => bail!("cost profile: top level is not a JSON object"),
        };
        let cases = root
            .into_iter()
            .find(|(k, _)| k == "cases")
            .map(|(_, v)| v)
            .ok_or_else(|| anyhow!("cost profile: no \"cases\" object"))?;
        let json::Value::Object(cases) = cases else {
            bail!("cost profile: \"cases\" is not an object");
        };
        let mut profile = CostProfile::new();
        for (layer, metrics) in cases {
            let json::Value::Object(metrics) = metrics else {
                bail!("cost profile: layer {layer:?} is not an object");
            };
            let mut cost = LayerCost::default();
            for (key, value) in metrics {
                let json::Value::Number(x) = value else {
                    bail!(
                        "cost profile: {layer:?}.{key} is not a number"
                    );
                };
                match key.as_str() {
                    "decode_ns" => cost.decode_ns = x,
                    "gemv_ns" => cost.gemv_ns = x,
                    "decode_samples" => {
                        cost.decode_samples = as_count(&layer, &key, x)?
                    }
                    "gemv_samples" => {
                        cost.gemv_samples = as_count(&layer, &key, x)?
                    }
                    _ => {} // forward compatibility
                }
            }
            if profile.entries.insert(layer.clone(), cost).is_some() {
                bail!("cost profile: layer {layer:?} appears twice");
            }
        }
        Ok(profile)
    }
}

fn as_count(layer: &str, key: &str, x: f64) -> Result<u64> {
    if x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64
    {
        Ok(x as u64)
    } else {
        bail!("cost profile: {layer:?}.{key} is not a sample count ({x})")
    }
}

/// Partition the container's layers across `n_shards` by *observed*
/// decode cost: the same greedy lightest-shard loop as
/// `ShardAssignment::ByBytes` ([`ShardMap::assign_by_weight`]), but
/// weighted by the profile's predicted decode ns instead of compressed
/// record bytes. The profile must cover the container exactly (see
/// module docs); the returned map passes the same validation as a
/// parsed `F2F3` sidecar.
pub fn rebalance_map(
    index: &ContainerIndex,
    n_shards: usize,
    profile: &CostProfile,
) -> Result<ShardMap> {
    if n_shards == 0 {
        bail!("rebalance needs at least one shard");
    }
    for e in index.entries() {
        let Some(cost) = profile.get(&e.name) else {
            bail!(
                "cost profile has no entry for layer {:?} — stale \
                 profile, or one from a different model?",
                e.name
            );
        };
        if cost.decode_samples == 0 {
            bail!(
                "cost profile has no decode observations for layer \
                 {:?} — serve traffic (or run the bench) before \
                 rebalancing",
                e.name
            );
        }
        if !cost.decode_ns.is_finite() || cost.decode_ns < 0.0 {
            bail!(
                "cost profile decode_ns for layer {:?} is not a sane \
                 duration ({})",
                e.name,
                cost.decode_ns
            );
        }
    }
    for (name, _) in profile.entries() {
        if index.find(&name).is_none() {
            bail!(
                "cost profile names layer {name:?} which the container \
                 does not have — stale profile, or one from a \
                 different model?"
            );
        }
    }
    // Every indexed layer was validated present (and sane) above, so
    // the 0.0 fallback is unreachable — it exists so a future edit to
    // the validation can never reintroduce a panic here.
    ShardMap::assign_by_weight(index, n_shards, |e| {
        profile.get(&e.name).map_or(0.0, |c| c.decode_ns)
    })
}

/// Minimal JSON reader for the flat profile shape (serde is
/// unavailable offline, and [`JsonReport`] is write-only). Supports
/// objects, strings and numbers — exactly what the profile needs —
/// and rejects everything else cleanly: unknown tokens (`NaN`,
/// `Infinity`, arrays), numbers that overflow to non-finite values,
/// duplicate keys inside any object, and nesting past a fixed depth
/// cap (the recursive-descent parser must error, not exhaust the
/// stack, on `{"a":{"a":{…` bombs). Crate-visible: the live-stats
/// snapshot (`crate::obs::stats`) deliberately restricts itself to
/// the same objects-and-numbers shape so `f2f top` parses it with
/// this same hardened reader.
pub(crate) mod json {
    use anyhow::{bail, Result};

    /// Nesting bound: the profile shape is 3 levels deep; anything
    /// past this is hostile input, rejected before recursion can
    /// threaten the stack.
    pub const MAX_DEPTH: usize = 16;

    #[derive(Debug)]
    pub enum Value {
        Object(Vec<(String, Value)>),
        Number(f64),
        #[allow(dead_code)] // parsed (the title field) but never read
        String(String),
    }

    pub fn parse(s: &str) -> Result<Value> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes after JSON value (offset {})", p.i);
        }
        Ok(v)
    }

    struct Parser<'a> {
        b: &'a [u8],
        i: usize,
        depth: usize,
    }

    impl Parser<'_> {
        fn ws(&mut self) {
            while self
                .b
                .get(self.i)
                .is_some_and(|c| c.is_ascii_whitespace())
            {
                self.i += 1;
            }
        }

        fn peek(&self) -> Option<u8> {
            self.b.get(self.i).copied()
        }

        fn expect_byte(&mut self, c: u8) -> Result<()> {
            if self.peek() == Some(c) {
                self.i += 1;
                Ok(())
            } else {
                bail!(
                    "expected {:?} at offset {} ({:?} found)",
                    c as char,
                    self.i,
                    self.peek().map(|b| b as char)
                );
            }
        }

        fn value(&mut self) -> Result<Value> {
            match self.peek() {
                Some(b'{') => self.object(),
                Some(b'"') => Ok(Value::String(self.string()?)),
                Some(c) if c == b'-' || c.is_ascii_digit() => {
                    self.number()
                }
                other => bail!(
                    "unsupported JSON value at offset {} ({:?})",
                    self.i,
                    other.map(|b| b as char)
                ),
            }
        }

        fn object(&mut self) -> Result<Value> {
            if self.depth >= MAX_DEPTH {
                bail!(
                    "JSON nested deeper than {MAX_DEPTH} levels \
                     (offset {})",
                    self.i
                );
            }
            self.depth += 1;
            let fields = self.object_fields();
            self.depth -= 1;
            fields.map(Value::Object)
        }

        fn object_fields(&mut self) -> Result<Vec<(String, Value)>> {
            self.expect_byte(b'{')?;
            let mut fields: Vec<(String, Value)> = Vec::new();
            self.ws();
            if self.peek() == Some(b'}') {
                self.i += 1;
                return Ok(fields);
            }
            loop {
                self.ws();
                let key = self.string()?;
                if fields.iter().any(|(k, _)| *k == key) {
                    bail!("duplicate JSON key {key:?}");
                }
                self.ws();
                self.expect_byte(b':')?;
                self.ws();
                let value = self.value()?;
                fields.push((key, value));
                self.ws();
                match self.peek() {
                    Some(b',') => self.i += 1,
                    Some(b'}') => {
                        self.i += 1;
                        return Ok(fields);
                    }
                    other => bail!(
                        "expected ',' or '}}' at offset {} ({:?})",
                        self.i,
                        other.map(|b| b as char)
                    ),
                }
            }
        }

        fn string(&mut self) -> Result<String> {
            self.expect_byte(b'"')?;
            let mut out = String::new();
            loop {
                match self.peek() {
                    None => bail!("unterminated JSON string"),
                    Some(b'"') => {
                        self.i += 1;
                        return Ok(out);
                    }
                    Some(b'\\') => {
                        self.i += 1;
                        match self.peek() {
                            Some(b'"') => out.push('"'),
                            Some(b'\\') => out.push('\\'),
                            Some(b'/') => out.push('/'),
                            Some(b'n') => out.push('\n'),
                            Some(b't') => out.push('\t'),
                            Some(b'r') => out.push('\r'),
                            Some(b'u') => {
                                let hex = self
                                    .b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or_else(|| {
                                        anyhow::anyhow!(
                                            "truncated \\u escape"
                                        )
                                    })?;
                                let code = u32::from_str_radix(
                                    std::str::from_utf8(hex)?,
                                    16,
                                )?;
                                let Some(c) = char::from_u32(code)
                                else {
                                    bail!(
                                        "invalid \\u escape {code:#x}"
                                    );
                                };
                                out.push(c);
                                self.i += 4;
                            }
                            other => bail!(
                                "unsupported escape {:?}",
                                other.map(|b| b as char)
                            ),
                        }
                        self.i += 1;
                    }
                    Some(_) => {
                        // Copy one UTF-8 scalar (the input is a &str,
                        // so boundaries are valid by construction —
                        // but decode defensively all the same).
                        let rest =
                            self.b.get(self.i..).unwrap_or_default();
                        let tail = std::str::from_utf8(rest)
                            .map_err(|_| {
                                anyhow::anyhow!(
                                    "invalid UTF-8 in JSON string"
                                )
                            })?;
                        let Some(c) = tail.chars().next() else {
                            bail!("unterminated JSON string");
                        };
                        out.push(c);
                        self.i += c.len_utf8();
                    }
                }
            }
        }

        fn number(&mut self) -> Result<Value> {
            let start = self.i;
            while self.peek().is_some_and(|c| {
                c.is_ascii_digit()
                    || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            }) {
                self.i += 1;
            }
            let digits = self.b.get(start..self.i).unwrap_or_default();
            let text = std::str::from_utf8(digits).map_err(|_| {
                anyhow::anyhow!("bad JSON number at offset {start}")
            })?;
            let v: f64 = text
                .parse()
                .map_err(|_| anyhow::anyhow!("bad JSON number {text:?}"))?;
            // `1e999` parses to infinity in Rust; a profile carrying
            // it would poison every downstream cost comparison, so
            // non-finite numbers are rejected at the gate.
            if !v.is_finite() {
                bail!("non-finite JSON number {text:?}");
            }
            Ok(Value::Number(v))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::write_container_v2;
    use crate::models::{compressed_mlp, MlpConfig};

    fn cost(decode_ns: f64) -> LayerCost {
        LayerCost {
            decode_ns,
            decode_samples: 4,
            gemv_ns: 10.0,
            gemv_samples: 4,
        }
    }

    fn indexed_mlp(dims: &[usize]) -> (ContainerIndex, Vec<u8>) {
        let (c, _) = compressed_mlp(&MlpConfig {
            seed: 70,
            sparsity: 0.75,
            n_s: 0,
            beam: None,
            ..MlpConfig::new(dims)
        });
        let bytes = write_container_v2(&c);
        (ContainerIndex::parse(&bytes).unwrap(), bytes)
    }

    #[test]
    fn profile_json_round_trips() {
        let mut p = CostProfile::new();
        p.record("mlp/fc0", cost(1234.5));
        p.record("mlp/fc1", cost(99.0));
        let json = p.to_json();
        assert!(json.contains("\"decode_ns\": 1234.5"));
        let parsed = CostProfile::parse_json(&json).unwrap();
        assert_eq!(parsed, p);
        // Recording the same layer twice merges, sample-weighted.
        let mut q = CostProfile::new();
        q.record("a", cost(100.0));
        q.record("a", cost(300.0));
        assert_eq!(q.get("a").unwrap().decode_ns, 200.0);
        assert_eq!(q.get("a").unwrap().decode_samples, 8);
    }

    #[test]
    fn malformed_profiles_error_and_never_panic() {
        for bad in [
            "",
            "not json",
            "{\"title\": \"x\"}",                        // no cases
            "{\"cases\": 3}",                            // wrong type
            "{\"cases\": {\"a\": 1}}",                   // case not object
            "{\"cases\": {\"a\": {\"decode_ns\": \"soon\"}}}",
            "{\"cases\": {\"a\": {\"decode_samples\": 1.5}}}",
            "{\"cases\": {\"a\": {\"decode_samples\": -2}}}",
            "{\"cases\": {\"a\": {}}} trailing",
            "{\"cases\": {\"a\": {\"decode_ns\": 1}, \
              \"a\": {\"decode_ns\": 2}}}",
        ] {
            assert!(
                CostProfile::parse_json(bad).is_err(),
                "must reject {bad:?}"
            );
        }
        // Unknown metric keys are tolerated (forward compatibility).
        let ok = CostProfile::parse_json(
            "{\"title\": \"t\", \"cases\": {\"a\": \
             {\"decode_ns\": 5, \"decode_samples\": 1, \
              \"novel_metric\": 7}}}",
        )
        .unwrap();
        assert_eq!(ok.get("a").unwrap().decode_ns, 5.0);
    }

    #[test]
    fn adversarial_json_errors_and_never_panics() {
        // Truncated objects at every prefix of a valid profile.
        let mut p = CostProfile::new();
        p.record("fc0", cost(10.0));
        let valid = p.to_json();
        for cut in 0..valid.len() {
            if !valid.is_char_boundary(cut) {
                continue;
            }
            let _ = CostProfile::parse_json(&valid[..cut]);
        }
        assert!(
            CostProfile::parse_json(&valid).is_ok(),
            "the uncut profile still parses"
        );

        // NaN / Infinity tokens, and numbers that overflow to
        // non-finite values.
        for bad in [
            "{\"cases\": {\"a\": {\"decode_ns\": NaN}}}",
            "{\"cases\": {\"a\": {\"decode_ns\": Infinity}}}",
            "{\"cases\": {\"a\": {\"decode_ns\": -Infinity}}}",
            "{\"cases\": {\"a\": {\"decode_ns\": 1e999}}}",
            "{\"cases\": {\"a\": {\"decode_ns\": -1e999}}}",
        ] {
            let err = CostProfile::parse_json(bad).unwrap_err();
            let msg = format!("{err}");
            assert!(
                msg.contains("JSON") || msg.contains("number"),
                "{bad:?}: {msg}"
            );
        }

        // Duplicate keys at every object level are rejected.
        for dup in [
            "{\"cases\": {}, \"cases\": {}}",
            "{\"cases\": {\"a\": {\"decode_ns\": 1}, \
              \"a\": {\"decode_ns\": 2}}}",
            "{\"cases\": {\"a\": {\"decode_ns\": 1, \
              \"decode_ns\": 2}}}",
        ] {
            let err = CostProfile::parse_json(dup).unwrap_err();
            assert!(
                format!("{err}").contains("duplicate"),
                "{dup:?}: {err}"
            );
        }

        // A nesting bomb must error at the depth cap, not exhaust
        // the parser's stack.
        let mut bomb = String::new();
        for _ in 0..10_000 {
            bomb.push_str("{\"a\":");
        }
        bomb.push('1');
        for _ in 0..10_000 {
            bomb.push('}');
        }
        let err = CostProfile::parse_json(&bomb).unwrap_err();
        assert!(
            format!("{err}").contains("nested deeper"),
            "{err}"
        );

        // Byte-flip fuzz over a valid profile: parse or reject,
        // never panic.
        let bytes = valid.as_bytes();
        for pos in 0..bytes.len() {
            for val in [b' ', b'"', b'{', b'}', b'0', b'\xff'] {
                if bytes[pos] == val {
                    continue;
                }
                let mut corrupt = bytes.to_vec();
                corrupt[pos] = val;
                if let Ok(s) = String::from_utf8(corrupt) {
                    let _ = CostProfile::parse_json(&s);
                }
            }
        }
    }

    #[test]
    fn rebalance_splits_on_observed_cost_not_bytes() {
        // Four equal-width layers, but the profile says fc0 is as
        // expensive as the other three combined: cost-greedy must pair
        // fc0 alone against the rest — byte balancing never would,
        // because the records are near-identical in size.
        let (index, _) = indexed_mlp(&[16, 16, 16, 16, 16]);
        let mut profile = CostProfile::new();
        profile.record("fc0", cost(3000.0));
        profile.record("fc1", cost(1000.0));
        profile.record("fc2", cost(1000.0));
        profile.record("fc3", cost(1000.0));
        let map = rebalance_map(&index, 2, &profile).unwrap();
        assert_eq!(map.shard_of("fc0"), Some(0));
        assert_eq!(map.shard_of("fc1"), Some(1));
        assert_eq!(map.shard_of("fc2"), Some(1));
        assert_eq!(map.shard_of("fc3"), Some(1));
        let loads = profile.shard_loads(&map);
        assert_eq!(loads, vec![3000.0, 3000.0], "perfectly balanced");
        // Deterministic, and the emitted sidecar passes the standard
        // corrupt-map validation round trip.
        assert_eq!(rebalance_map(&index, 2, &profile).unwrap(), map);
        assert_eq!(ShardMap::parse(&map.to_bytes()).unwrap(), map);
    }

    #[test]
    fn stale_or_mismatched_profiles_are_rejected() {
        let (index, _) = indexed_mlp(&[16, 12, 8]);
        // Missing layer.
        let mut missing = CostProfile::new();
        missing.record("fc0", cost(10.0));
        let err = rebalance_map(&index, 2, &missing).unwrap_err();
        assert!(format!("{err}").contains("no entry"), "{err}");
        // Extra (renamed) layer: a profile from a different model.
        let mut extra = CostProfile::new();
        extra.record("fc0", cost(10.0));
        extra.record("fc1", cost(10.0));
        extra.record("ghost", cost(10.0));
        let err = rebalance_map(&index, 2, &extra).unwrap_err();
        assert!(
            format!("{err}").contains("does not have"),
            "{err}"
        );
        // No decode observations.
        let mut unwarmed = CostProfile::new();
        unwarmed.record("fc0", LayerCost::default());
        unwarmed.record("fc1", LayerCost::default());
        let err = rebalance_map(&index, 2, &unwarmed).unwrap_err();
        assert!(
            format!("{err}").contains("no decode observations"),
            "{err}"
        );
        // Non-finite cost.
        let mut cursed = CostProfile::new();
        cursed.record(
            "fc0",
            LayerCost {
                decode_ns: f64::INFINITY,
                decode_samples: 1,
                ..Default::default()
            },
        );
        cursed.record("fc1", cost(10.0));
        let err = rebalance_map(&index, 2, &cursed).unwrap_err();
        assert!(format!("{err}").contains("sane duration"), "{err}");
        // Zero shards.
        let full = {
            let mut p = CostProfile::new();
            p.record("fc0", cost(1.0));
            p.record("fc1", cost(1.0));
            p
        };
        assert!(rebalance_map(&index, 0, &full).is_err());
    }
}

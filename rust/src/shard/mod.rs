//! Sharded serving: one compressed model, N independent stores.
//!
//! The paper's fixed-to-fixed format makes every layer's compressed
//! record a fixed, independently addressable unit — which is exactly
//! what lets a model scale *horizontally*: split the v2 container with
//! a [`crate::container::ShardMap`] (magic `F2F3`), open one
//! byte-budgeted [`crate::store::ModelStore`] per shard file (each with
//! its own persistent decode service, and — under the `mmap` feature —
//! its own lazily-paged file mapping), and let a [`ShardRouter`] drive
//! the forward chain across them:
//!
//! * each layer's pinned fetch goes to the store that owns it;
//! * readahead is *cross-shard*: while layer `i`'s GEMV runs, layer
//!   `i+1` warms on **its** shard's decode workers, so cold decode
//!   parallelism multiplies with the shard count instead of queueing
//!   on one service (with `--readahead auto`, depth is planned per
//!   layer from each shard's observed cost table);
//! * per-shard metrics fold into one aggregate [`ShardMetrics`]
//!   snapshot, including the merged per-layer cost table.
//!
//! The router implements the coordinator's [`crate::coordinator::Backend`],
//! so it drops behind an [`crate::coordinator::InferenceServer`] exactly
//! like the single-store [`crate::store::ModelBackend`] — and produces
//! bit-identical outputs (same decode, same GEMV order).
//!
//! The partition itself can follow the measurements too: export the
//! merged table as a [`CostProfile`] and let [`rebalance_map`]
//! re-shard on observed per-layer decode time instead of compressed
//! bytes (`f2f rebalance`; see [`rebalance`]).

pub mod rebalance;
mod router;

pub use rebalance::{rebalance_map, CostProfile};
pub use router::{ShardMetrics, ShardRouter};

//! The shard router: a multi-store [`Backend`] for split models.

use super::rebalance::CostProfile;
use crate::container::ShardMap;
use crate::coordinator::Backend;
use crate::store::{
    forward_chain, validate_chain, LayerCost, ModelStore,
    ReadaheadPolicy, StoreConfig, StoreMetrics,
};
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// One step of the forward chain: the layer and the shard that owns it.
struct ChainLink {
    name: String,
    shard: usize,
}

/// Aggregated router metrics: one snapshot per shard store, their
/// field-wise sum (see [`StoreMetrics::merge`] — counters add, decode
/// and GEMV latency histograms merge exactly), and the merged
/// per-layer cost table the stores observed.
#[derive(Debug, Clone)]
pub struct ShardMetrics {
    /// Per-shard snapshots, indexed by shard id.
    pub per_shard: Vec<StoreMetrics>,
    /// Field-wise sum across shards.
    pub total: StoreMetrics,
    /// Per-layer observed costs merged across every shard store
    /// (name-ordered; each layer normally lives on exactly one shard,
    /// so merging is a union — see [`LayerCost::merge`]).
    pub costs: Vec<(String, LayerCost)>,
}

/// A sequential GEMV chain served from N independent [`ModelStore`]s,
/// routed layer-by-layer through a [`ShardMap`]. Implements the
/// coordinator's [`Backend`]; outputs are bit-identical to the
/// single-store [`crate::store::ModelBackend`] on the same container.
pub struct ShardRouter {
    shards: Vec<Arc<ModelStore>>,
    chain: Vec<ChainLink>,
    readahead: ReadaheadPolicy,
    input_dim: usize,
    output_dim: usize,
}

impl ShardRouter {
    /// Build a router over already-open stores (`shards[i]` serves
    /// shard `i` of `map`). Validates that the store count matches the
    /// map, that every assigned layer exists in its owning store, and
    /// that consecutive chain dimensions line up — all from the
    /// indexes; nothing is decoded here.
    pub fn new(
        shards: Vec<Arc<ModelStore>>,
        map: &ShardMap,
    ) -> Result<Self> {
        if map.n_shards() != shards.len() {
            bail!(
                "shard map names {} shards but {} stores were supplied",
                map.n_shards(),
                shards.len()
            );
        }
        if map.is_empty() {
            bail!("shard map assigns no layers");
        }
        let mut chain = Vec::with_capacity(map.len());
        let mut dims = Vec::with_capacity(map.len());
        for (name, shard) in map.assignments() {
            let Some(d) = shards[*shard].layer_dims(name) else {
                bail!(
                    "layer {name:?} assigned to shard {shard} but \
                     missing from that store"
                );
            };
            dims.push(d);
            chain.push(ChainLink { name: name.clone(), shard: *shard });
        }
        let names: Vec<&str> =
            chain.iter().map(|l| l.name.as_str()).collect();
        let (input_dim, output_dim) = validate_chain(&names, &dims)?;
        Ok(ShardRouter {
            input_dim,
            output_dim,
            shards,
            chain,
            readahead: ReadaheadPolicy::default(),
        })
    }

    /// Parse a serialized shard map and open one store per shard's
    /// serialized v2 bytes (all with the same `config`).
    pub fn from_bytes(
        map_bytes: &[u8],
        shard_bytes: Vec<Vec<u8>>,
        config: StoreConfig,
    ) -> Result<Self> {
        let map = ShardMap::parse(map_bytes)?;
        let shards = shard_bytes
            .into_iter()
            .enumerate()
            .map(|(i, b)| {
                ModelStore::open_bytes(b, config)
                    .map(Arc::new)
                    .with_context(|| format!("opening shard {i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        Self::new(shards, &map)
    }

    /// Open a sharded model from disk: the `F2F3` map file plus one v2
    /// container file per shard (in shard-id order). With the `mmap`
    /// feature each shard store maps its file, so a shard pages in only
    /// the records it decodes.
    pub fn open_paths<P: AsRef<Path>>(
        map_path: impl AsRef<Path>,
        shard_paths: &[P],
        config: StoreConfig,
    ) -> Result<Self> {
        let map_path = map_path.as_ref();
        let map_bytes = std::fs::read(map_path).with_context(|| {
            format!("reading shard map {}", map_path.display())
        })?;
        let map = ShardMap::parse(&map_bytes)?;
        let shards = shard_paths
            .iter()
            .enumerate()
            .map(|(i, p)| {
                ModelStore::open_path(p.as_ref(), config)
                    .map(Arc::new)
                    .with_context(|| format!("opening shard {i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        Self::new(shards, &map)
    }

    /// Replace the readahead policy (builder style).
    pub fn with_readahead(mut self, policy: ReadaheadPolicy) -> Self {
        self.readahead = policy;
        self
    }

    /// Replace the readahead policy in place.
    pub fn set_readahead(&mut self, policy: ReadaheadPolicy) {
        self.readahead = policy;
    }

    /// The active readahead policy.
    pub fn readahead(&self) -> ReadaheadPolicy {
        self.readahead
    }

    /// The per-shard stores, indexed by shard id.
    pub fn shards(&self) -> &[Arc<ModelStore>] {
        &self.shards
    }

    /// Layer names in forward order.
    pub fn chain(&self) -> Vec<&str> {
        self.chain.iter().map(|l| l.name.as_str()).collect()
    }

    /// Warm the front of the chain, stopping once any shard's budget
    /// would be exceeded by its own share of the warmed prefix (the
    /// per-shard counterpart of `ModelBackend::prefetch_all`: early
    /// layers — the ones traffic needs first — end up hot, never
    /// decode-then-evict churn). The first layer is always warmed.
    pub fn prefetch_all(&self) -> Result<()> {
        let mut used = vec![0usize; self.shards.len()];
        for (i, link) in self.chain.iter().enumerate() {
            let store = &self.shards[link.shard];
            let bytes =
                store.layer_planned_bytes(&link.name).unwrap_or(0);
            if i > 0
                && used[link.shard].saturating_add(bytes)
                    > store.budget_bytes()
            {
                break;
            }
            used[link.shard] = used[link.shard].saturating_add(bytes);
            store.prefetch(&link.name)?;
        }
        Ok(())
    }

    /// Block until no shard has a decode in flight (test / drain aid).
    pub fn wait_for_idle(&self) {
        for s in &self.shards {
            s.wait_for_idle();
        }
    }

    /// Aggregate metrics snapshot across every shard store.
    pub fn metrics(&self) -> ShardMetrics {
        let per_shard: Vec<StoreMetrics> =
            self.shards.iter().map(|s| s.metrics()).collect();
        let mut total = StoreMetrics::default();
        for m in &per_shard {
            total.merge(m);
        }
        ShardMetrics {
            per_shard,
            total,
            costs: self.cost_profile().entries(),
        }
    }

    /// The merged observed-cost table as a serializable
    /// [`CostProfile`] — the input `f2f rebalance` consumes to
    /// re-partition the model on measured decode time.
    pub fn cost_profile(&self) -> CostProfile {
        CostProfile::from_stores(self.shards.iter().map(|s| s.costs()))
    }
}

impl Backend for ShardRouter {
    fn forward_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        // Trace context for callers outside the inference server (the
        // server pins the batch leader's trace before calling in).
        let _trace = crate::obs::ensure_trace();
        // Resolve each chain step to its owning shard's store and run
        // the exact same inner loop as the single-store `ModelBackend`
        // (bit-identical outputs by construction). Readahead targets
        // resolve to *their* shard, so upcoming layers warm on their
        // own decode workers while this shard's GEMVs run — cold
        // decode parallelism scales with the shard count.
        let links: Vec<(&ModelStore, &str)> = self
            .chain
            .iter()
            .map(|l| (self.shards[l.shard].as_ref(), l.name.as_str()))
            .collect();
        forward_chain(&links, self.readahead, xs)
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn output_dim(&self) -> usize {
        self.output_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{
        write_container_v2, write_sharded, ShardAssignment,
    };
    use crate::store::{test_model as model, ModelBackend};

    fn open_all(
        shard_bytes: Vec<Vec<u8>>,
        config: StoreConfig,
    ) -> Vec<Arc<ModelStore>> {
        shard_bytes
            .into_iter()
            .map(|b| Arc::new(ModelStore::open_bytes(b, config).unwrap()))
            .collect()
    }

    #[test]
    fn router_matches_single_store_bit_exact() {
        let c = model(&[20, 16, 12, 8], 60);
        let xs: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                (0..20).map(|j| ((i * j) as f32 * 0.1).sin()).collect()
            })
            .collect();
        let single = Arc::new(ModelStore::from_container(
            c.clone(),
            StoreConfig::default(),
        ));
        let want = ModelBackend::sequential(single)
            .unwrap()
            .forward_batch(&xs)
            .unwrap();
        for strategy in
            [ShardAssignment::RoundRobin, ShardAssignment::ByBytes]
        {
            let (map, shard_bytes) =
                write_sharded(&c, 2, strategy).unwrap();
            let mut router = ShardRouter::new(
                open_all(shard_bytes, StoreConfig::default()),
                &map,
            )
            .unwrap();
            assert_eq!(router.input_dim(), 20);
            assert_eq!(router.output_dim(), 8);
            assert_eq!(router.chain(), vec!["fc0", "fc1", "fc2"]);
            let got = router.forward_batch(&xs).unwrap();
            assert_eq!(got, want, "{strategy:?} must be bit-exact");
            router.wait_for_idle();
            let m = router.metrics();
            assert_eq!(m.per_shard.len(), 2);
            assert_eq!(m.total.decodes, 3, "each layer decodes once");
            assert_eq!(m.total.redundant_decodes, 0);
            assert_eq!(m.total.pinned_bytes, 0);
        }
    }

    #[test]
    fn from_bytes_round_trips_the_sidecar() {
        let c = model(&[16, 12, 8], 61);
        let (map, shard_bytes) =
            write_sharded(&c, 2, ShardAssignment::ByBytes).unwrap();
        let mut router = ShardRouter::from_bytes(
            &map.to_bytes(),
            shard_bytes,
            StoreConfig::default(),
        )
        .unwrap()
        .with_readahead(ReadaheadPolicy::off());
        assert!(!router.readahead().enabled());
        let ys = router.forward_batch(&[vec![0.25; 16]]).unwrap();
        assert_eq!(ys[0].len(), 8);
    }

    #[test]
    fn rejects_mismatched_store_count_and_missing_layers() {
        let c = model(&[16, 12, 8], 62);
        let (map, shard_bytes) =
            write_sharded(&c, 2, ShardAssignment::RoundRobin).unwrap();
        // One store short of the map's shard count.
        let one = open_all(
            vec![shard_bytes[0].clone()],
            StoreConfig::default(),
        );
        let err = ShardRouter::new(one, &map).unwrap_err();
        assert!(format!("{err}").contains("2 shards but 1 stores"));
        // Stores swapped: every layer is missing from its mapped store.
        let mut swapped = shard_bytes;
        swapped.reverse();
        let err = ShardRouter::new(
            open_all(swapped, StoreConfig::default()),
            &map,
        )
        .unwrap_err();
        assert!(
            format!("{err}").contains("missing from that store"),
            "{err}"
        );
    }

    #[test]
    fn rejects_incompatible_chain_dims() {
        // Two containers whose maps collide: build a model whose chain
        // dims don't line up by splitting a valid model and then
        // serving shard files from a *different* geometry under the
        // original map — simplest is a 1-shard map over a reversed
        // chain, which new() must reject via the dim check.
        let c = model(&[20, 16, 12], 63);
        let mut rev = c.clone();
        rev.layers.reverse();
        let bytes = write_container_v2(&rev);
        let (map, shard_bytes) = crate::container::split_container(
            &bytes,
            1,
            ShardAssignment::RoundRobin,
        )
        .unwrap();
        let err = ShardRouter::new(
            open_all(shard_bytes, StoreConfig::default()),
            &map,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("chain mismatch"), "{err}");
    }

    #[test]
    fn shard_metrics_aggregate_counters_and_cost_tables() {
        // Direct coverage of ShardMetrics: total must equal the
        // field-wise fold of per_shard (timing fields included), and
        // the merged cost table must union every shard's observations.
        let c = model(&[20, 16, 12, 8], 65);
        let (map, shard_bytes) =
            write_sharded(&c, 2, ShardAssignment::RoundRobin).unwrap();
        let mut router = ShardRouter::new(
            open_all(shard_bytes, StoreConfig::default()),
            &map,
        )
        .unwrap();
        let xs: Vec<Vec<f32>> = (0..2).map(|_| vec![0.3; 20]).collect();
        router.forward_batch(&xs).unwrap();
        router.wait_for_idle();
        let m = router.metrics();
        let mut folded = StoreMetrics::default();
        for s in &m.per_shard {
            folded.merge(s);
        }
        assert_eq!(m.total, folded, "total must be the per-shard fold");
        assert!(m.total.decode_ns_total > 0, "decode time observed");
        assert!(m.total.gemv_ns_total > 0, "gemv time observed");
        // Every chain layer shows up exactly once in the merged table,
        // name-ordered, with both cost dimensions sampled.
        let names: Vec<&str> =
            m.costs.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["fc0", "fc1", "fc2"]);
        for (name, cost) in &m.costs {
            assert_eq!(cost.decode_samples, 1, "{name}");
            assert_eq!(cost.gemv_samples, 1, "{name}");
        }
        // And the profile view matches the table view.
        let profile = router.cost_profile();
        assert_eq!(profile.entries(), m.costs);
        assert_eq!(profile.len(), 3);
    }

    #[test]
    fn prefetch_all_warms_front_within_per_shard_budgets() {
        let dims = [16usize, 16, 16, 16, 16];
        let c = model(&dims, 64);
        let layer_bytes = 16 * 16 * 4;
        let (map, shard_bytes) =
            write_sharded(&c, 2, ShardAssignment::RoundRobin).unwrap();
        // Each shard holds 2 layers; budget one layer per shard.
        let router = ShardRouter::new(
            open_all(
                shard_bytes,
                StoreConfig {
                    cache_budget_bytes: layer_bytes,
                    decode_workers: 1,
                    ..StoreConfig::default()
                },
            ),
            &map,
        )
        .unwrap();
        router.prefetch_all().unwrap();
        // fc0 (shard 0) and fc1 (shard 1) fit; fc2 would overflow
        // shard 0's budget, so warming stops before churn.
        assert!(router.shards()[0].is_cached("fc0"));
        assert!(router.shards()[1].is_cached("fc1"));
        assert!(!router.shards()[0].is_cached("fc2"));
        let m = router.metrics();
        assert_eq!(m.total.decodes, 2);
        assert_eq!(m.total.evictions, 0);
    }
}

//! `n_u` statistics: mean, variance, coefficient of variation (Eq. 3–5).
//!
//! For Bernoulli pruning, `n_u ~ B(N_out, 1−S)` so
//! `CV = √(Var)/E = √(S / (N_out(1−S)))` — Appendix A, Eq. 5. Structured
//! fine-grained pruners are overdispersed relative to this; the paper
//! correlates higher CV with lower encoding efficiency (Table 3).

use crate::gf2::BitVecF2;

/// Distribution summary of per-block unpruned counts.
#[derive(Debug, Clone, PartialEq)]
pub struct MaskStats {
    /// Block width used for slicing.
    pub n_out: usize,
    /// Number of blocks measured.
    pub blocks: usize,
    /// Mean of `n_u`.
    pub mean: f64,
    /// Variance of `n_u` (population).
    pub variance: f64,
    /// Coefficient of variation `√Var / mean` (0 when mean = 0).
    pub coeff_var: f64,
    /// Overall density (unpruned fraction) = `1 − S` measured.
    pub density: f64,
    /// Histogram of `n_u` values (index = count).
    pub histogram: Vec<usize>,
}

impl MaskStats {
    /// Slice `mask` into `n_out`-bit blocks and summarize `n_u`.
    /// Only full blocks are counted (tail excluded) so the binomial
    /// comparison is clean.
    pub fn from_mask(mask: &BitVecF2, n_out: usize) -> Self {
        let full_blocks = mask.len() / n_out;
        let mut hist = vec![0usize; n_out + 1];
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for t in 0..full_blocks {
            let n_u = mask.block(t * n_out, n_out).count_ones() as usize;
            hist[n_u] += 1;
            sum += n_u as f64;
            sum2 += (n_u * n_u) as f64;
        }
        let n = full_blocks.max(1) as f64;
        let mean = sum / n;
        let variance = (sum2 / n - mean * mean).max(0.0);
        let coeff_var =
            if mean > 0.0 { variance.sqrt() / mean } else { 0.0 };
        MaskStats {
            n_out,
            blocks: full_blocks,
            mean,
            variance,
            coeff_var,
            density: mean / n_out as f64,
            histogram: hist,
        }
    }

    /// Theoretical binomial coefficient of variation for sparsity `s`
    /// (Eq. 5 with `n_w = N_out`).
    pub fn binomial_cv(n_out: usize, s: f64) -> f64 {
        (s / (n_out as f64 * (1.0 - s))).sqrt()
    }

    /// Fraction of blocks whose `n_u` exceeds the decoder input width —
    /// blocks that *cannot* be perfectly encoded by a combinational
    /// decoder (§3.2's "too many unpruned weight bits").
    pub fn overflow_fraction(&self, n_in: usize) -> f64 {
        let over: usize = self
            .histogram
            .iter()
            .enumerate()
            .filter(|(n_u, _)| *n_u > n_in)
            .map(|(_, c)| c)
            .sum();
        over as f64 / self.blocks.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn exact_stats_on_known_mask() {
        // Blocks of 4: [1111, 0000, 1100] → n_u = 4, 0, 2.
        let mask = BitVecF2::from_bools(&[
            true, true, true, true, false, false, false, false, true, true,
            false, false,
        ]);
        let s = MaskStats::from_mask(&mask, 4);
        assert_eq!(s.blocks, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        // Var = (16+0+4)/3 − 4 = 8/3
        assert!((s.variance - 8.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.histogram[4], 1);
        assert_eq!(s.histogram[0], 1);
        assert_eq!(s.histogram[2], 1);
    }

    #[test]
    fn binomial_cv_formula() {
        // Paper §3.2: CV = √(S/(N_out(1−S))).
        let cv = MaskStats::binomial_cv(80, 0.9);
        assert!((cv - (0.9f64 / 8.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn bernoulli_mask_matches_binomial_cv() {
        let mut rng = Rng::new(1);
        let mask = BitVecF2::random(2_000_000, 0.1, &mut rng); // S=0.9
        let s = MaskStats::from_mask(&mask, 80);
        let expect = MaskStats::binomial_cv(80, 0.9);
        assert!(
            (s.coeff_var - expect).abs() < 0.02,
            "cv {} vs {}",
            s.coeff_var,
            expect
        );
        assert!((s.density - 0.1).abs() < 0.005);
    }

    #[test]
    fn overflow_fraction_counts_blocks_above_n_in() {
        let mask = BitVecF2::from_bools(&[
            true, true, true, false, // n_u = 3
            true, false, false, false, // n_u = 1
        ]);
        let s = MaskStats::from_mask(&mask, 4);
        assert!((s.overflow_fraction(2) - 0.5).abs() < 1e-12);
        assert_eq!(s.overflow_fraction(3), 0.0);
    }

    #[test]
    fn cv_increases_with_sparsity() {
        // Appendix A: CV grows with S — the reason fixed-to-variable
        // formats waste more bandwidth at higher sparsity.
        let mut rng = Rng::new(2);
        let lo = MaskStats::from_mask(
            &BitVecF2::random(500_000, 0.5, &mut rng),
            64,
        );
        let hi = MaskStats::from_mask(
            &BitVecF2::random(500_000, 0.05, &mut rng),
            64,
        );
        assert!(hi.coeff_var > lo.coeff_var);
    }
}

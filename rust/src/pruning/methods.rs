//! The four pruning-mask families used in §5.
//!
//! * **Random** — i.i.d. Bernoulli(S) per weight: the paper's synthetic
//!   baseline; `n_u ~ B(N_out, 1−S)` exactly.
//! * **Magnitude** — Han et al. 2015: prune the globally smallest `S`
//!   fraction of `|w|`. On weights with per-row scale variation (real
//!   networks, and our synthetic zoo) the per-row density varies, which
//!   overdisperses `n_u` relative to binomial — exactly the coefficient-
//!   of-variation gap the paper measures in Table 3.
//! * **L0Reg** — proxy for Louizos et al. 2018: magnitude scores modulated
//!   by row-correlated gate noise (L0's learned stochastic gates settle at
//!   per-neuron rates; the paper's Table 3 shows the highest coeff-var for
//!   L0 at S = 0.7).
//! * **VarDropout** — proxy for Molchanov et al. 2017: like L0 but with
//!   stronger per-row rate spread (Table S.4 shows var-dropout layers
//!   ranging from binomial-like up to coeff-var 0.77).
//!
//! The proxies do not retrain anything — they reproduce the *mask
//! statistics* the encoder is sensitive to (see DESIGN.md §2 for the
//! substitution argument).

use crate::gf2::BitVecF2;
use crate::rng::Rng;

/// Pruning mask family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PruneMethod {
    Random,
    Magnitude,
    L0Reg,
    VarDropout,
}

impl PruneMethod {
    /// Row-correlated score-noise strength for the proxy methods.
    fn row_noise(&self) -> f64 {
        match self {
            PruneMethod::Random => 0.0,
            PruneMethod::Magnitude => 0.0,
            // Calibrated so coeff-var(n_u) on the synthetic zoo matches
            // Table 3 / S.4: L0 slightly above magnitude (~0.33–0.47),
            // var-dropout spread reaching ~0.5+ on some layers.
            PruneMethod::L0Reg => 0.12,
            PruneMethod::VarDropout => 0.30,
        }
    }

    /// Short label used in report tables.
    pub fn label(&self) -> &'static str {
        match self {
            PruneMethod::Random => "Rand.",
            PruneMethod::Magnitude => "Mag.",
            PruneMethod::L0Reg => "L0 Reg.",
            PruneMethod::VarDropout => "Var. Dropout",
        }
    }
}

/// Mask generator: method + target sparsity + seed.
#[derive(Debug, Clone)]
pub struct Pruner {
    method: PruneMethod,
    sparsity: f64,
    seed: u64,
}

impl Pruner {
    /// `sparsity` is the pruned fraction `S ∈ [0, 1)`.
    pub fn new(method: PruneMethod, sparsity: f64, seed: u64) -> Self {
        assert!((0.0..1.0).contains(&sparsity));
        Pruner { method, sparsity, seed }
    }

    /// Pruned fraction `S`.
    pub fn sparsity(&self) -> f64 {
        self.sparsity
    }

    /// Mask method.
    pub fn method(&self) -> PruneMethod {
        self.method
    }

    /// Generate a mask (set bit = unpruned) for `weights`, flattened
    /// row-major with rows of `row_len` weights. `row_len` scopes the
    /// row-correlated noise of the L0/var-dropout proxies; it is ignored
    /// for Random and Magnitude.
    pub fn mask(&self, weights: &[f32], row_len: usize) -> BitVecF2 {
        let mut rng = Rng::new(self.seed);
        match self.method {
            PruneMethod::Random => {
                let keep = 1.0 - self.sparsity;
                BitVecF2::from_iter_bits(
                    weights.iter().map(|_| rng.bernoulli(keep)),
                )
            }
            _ => {
                let scores = self.scores(weights, row_len, &mut rng);
                threshold_mask(&scores, self.sparsity)
            }
        }
    }

    /// Importance scores (higher = keep).
    fn scores(
        &self,
        weights: &[f32],
        row_len: usize,
        rng: &mut Rng,
    ) -> Vec<f64> {
        let eta = self.method.row_noise();
        let n_rows = weights.len().div_ceil(row_len.max(1));
        let row_mult: Vec<f64> =
            (0..n_rows).map(|_| (eta * rng.normal()).exp()).collect();
        weights
            .iter()
            .enumerate()
            .map(|(i, &w)| {
                let r = i / row_len.max(1);
                (w.abs() as f64) * row_mult[r]
            })
            .collect()
    }
}

/// Keep the top `(1−S)` fraction by score (exact count, global quantile).
fn threshold_mask(scores: &[f64], sparsity: f64) -> BitVecF2 {
    let n = scores.len();
    let n_prune = ((n as f64) * sparsity).round() as usize;
    let mut idx: Vec<usize> = (0..n).collect();
    if n_prune > 0 && n_prune < n {
        idx.select_nth_unstable_by(n_prune - 1, |&a, &b| {
            scores[a].partial_cmp(&scores[b]).unwrap()
        });
    }
    let mut mask = BitVecF2::zeros(n);
    for &i in &idx[n_prune.min(n)..] {
        mask.set(i, true);
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::MaskStats;

    fn gaussian_weights(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Weights with lognormal per-row scale, like the synthetic zoo.
    fn row_scaled_weights(
        rows: usize,
        cols: usize,
        sigma: f64,
        seed: u64,
    ) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut w = Vec::with_capacity(rows * cols);
        for _ in 0..rows {
            let scale = (sigma * rng.normal()).exp();
            for _ in 0..cols {
                w.push((rng.normal() * scale) as f32);
            }
        }
        w
    }

    #[test]
    fn magnitude_prunes_smallest() {
        let w = vec![0.1f32, -5.0, 0.01, 3.0, -0.2, 0.02];
        let mask = Pruner::new(PruneMethod::Magnitude, 0.5, 1).mask(&w, 6);
        let kept: Vec<bool> = mask.iter().collect();
        assert_eq!(kept, vec![false, true, false, true, true, false]);
    }

    #[test]
    fn magnitude_exact_sparsity() {
        let w = gaussian_weights(10_000, 2);
        let mask = Pruner::new(PruneMethod::Magnitude, 0.9, 1).mask(&w, 100);
        assert_eq!(mask.count_ones(), 1000);
    }

    #[test]
    fn random_mask_nu_is_binomial_like() {
        // Coefficient of variation should match √(S/(N_out(1−S))) (Eq. 5).
        let w = gaussian_weights(400_000, 3);
        let mask = Pruner::new(PruneMethod::Random, 0.7, 4).mask(&w, 512);
        let stats = MaskStats::from_mask(&mask, 26);
        let expect = (0.7f64 / (26.0 * 0.3)).sqrt();
        assert!(
            (stats.coeff_var - expect).abs() < 0.03,
            "cv {} vs binomial {}",
            stats.coeff_var,
            expect
        );
    }

    #[test]
    fn structured_methods_are_overdispersed() {
        // On row-scaled weights, magnitude/L0/var-dropout masks must have
        // higher coeff-var than random (Table 3's ordering).
        let w = row_scaled_weights(512, 512, 0.25, 5);
        let cv = |m: PruneMethod| {
            let mask = Pruner::new(m, 0.7, 6).mask(&w, 512);
            MaskStats::from_mask(&mask, 26).coeff_var
        };
        let rand = cv(PruneMethod::Random);
        let mag = cv(PruneMethod::Magnitude);
        let vd = cv(PruneMethod::VarDropout);
        assert!(mag > rand, "mag {mag} vs rand {rand}");
        assert!(vd > mag * 0.9, "vd {vd} vs mag {mag}");
    }

    #[test]
    fn deterministic_in_seed() {
        let w = gaussian_weights(1000, 7);
        let a = Pruner::new(PruneMethod::L0Reg, 0.8, 9).mask(&w, 100);
        let b = Pruner::new(PruneMethod::L0Reg, 0.8, 9).mask(&w, 100);
        assert_eq!(a, b);
    }
}

//! Fine-grained pruning mask generation and `n_u` statistics.
//!
//! The encoder never sees weights directly — only a binary mask (pruned /
//! unpruned) and bit-planes. What matters for encoding capability is the
//! *distribution of `n_u`* (unpruned bits per `N_out`-block): random
//! pruning gives a binomial `n_u`; magnitude and L0 pruning are
//! overdispersed (higher coefficient of variation) because per-row weight
//! scales differ (§3.2, Table 3). We implement all four of the paper's
//! mask families.

mod methods;
mod stats;

pub use methods::{PruneMethod, Pruner};
pub use stats::MaskStats;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn all_methods_hit_target_sparsity() {
        let mut rng = Rng::new(1);
        let weights: Vec<f32> =
            (0..40_000).map(|_| rng.normal() as f32).collect();
        for method in [
            PruneMethod::Random,
            PruneMethod::Magnitude,
            PruneMethod::L0Reg,
            PruneMethod::VarDropout,
        ] {
            let mask = Pruner::new(method, 0.7, 7).mask(&weights, 200);
            let density =
                mask.count_ones() as f64 / weights.len() as f64;
            assert!(
                (density - 0.3).abs() < 0.02,
                "{method:?}: density {density}"
            );
        }
    }
}

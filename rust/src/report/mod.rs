//! Fixed-width table rendering + CSV for the repro harness.
//!
//! Every `f2f repro <id>` command prints its result through this module
//! so outputs are uniform, diffable, and easy to paste next to the
//! paper's tables in EXPERIMENTS.md.

/// A simple text table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringify cells yourself; use [`fmt_pct`] etc.).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(
            widths.iter().sum::<usize>() + 2 * (widths.len() - 1),
        ));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// `97.53` style percent cell.
pub fn fmt_pct(v: f64) -> String {
    format!("{v:.2}")
}

/// `97.5 (±0.36)` style mean±sd cell (Figure 4's format).
pub fn fmt_mean_sd(mean: f64, sd: f64) -> String {
    format!("{mean:.2} (±{sd:.2})")
}

/// `0.324` style ratio cell.
pub fn fmt_ratio(v: f64) -> String {
    format!("{v:.3}")
}

/// Mean and population standard deviation of a sample.
pub fn mean_sd(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["10".into(), "200".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new("demo", &["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "x,y\n1,2\n");
    }

    #[test]
    fn mean_sd_values() {
        let (m, s) = mean_sd(&[1.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}

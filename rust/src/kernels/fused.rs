//! Fused decode→GEMV execution: `y = W·x` straight from bit-planes.
//!
//! The materialized path decodes a layer into a dense f32 buffer
//! (`4·rows·cols` bytes) that the GEMV then walks. A [`FusedLayer`]
//! keeps the *decoded bit-planes* resident instead — corrections and
//! inversion already applied — and decodes 64 weights at a time into
//! registers during the GEMV itself, via [`transpose64`]. For I8 layers
//! the resident footprint is `(n_w+1)` bits per weight instead of 32
//! (~9/32 of dense), which relieves cache-eviction pressure, `Auto`
//! readahead admission, and IPC transfer size all at once; F32 layers
//! are slightly *larger* fused (33/32), which is why
//! [`DecodeMode::Auto`](super::DecodeMode) prices per layer.
//!
//! Planes and mask are repacked **row-padded**: every row starts on a
//! word boundary (`words_per_row = ⌈cols/64⌉`), so the per-row GEMV
//! reads whole words even when `cols % 64 != 0`. The f32 accumulation
//! is the exact op sequence of `DecodedLayer::gemv` — ascending column,
//! pruned terms included as `+0.0` — so fused and materialized outputs
//! are bit-exact, which `rust/tests/fused_parity.rs` pins down.

use super::transpose64;
use crate::container::{CompressedLayer, Dtype};
use crate::gf2::BitVecF2;
use crate::sparse::DecodedLayer;

/// A layer resident as decoded bit-planes + mask, executing GEMV
/// without ever materializing the dense f32 buffer.
#[derive(Debug, Clone)]
pub struct FusedLayer {
    rows: usize,
    cols: usize,
    dtype: Dtype,
    scale: f32,
    words_per_row: usize,
    /// Plane-major, row-padded words: plane `k`'s row `r` occupies
    /// `[k·rows·wpr + r·wpr ..][..wpr]`. Planes stay MSB-first (plane 0
    /// holds weight bit `n_w − 1`), matching the container layout.
    planes: Vec<u64>,
    /// Pruning mask in the same row-padded layout (set = unpruned).
    mask: Vec<u64>,
}

impl FusedLayer {
    /// Build from decoded (corrected, un-inverted) planes, repacking
    /// into the row-padded layout. Validates plane count and lengths —
    /// a malformed container becomes an error, never a panic.
    pub fn from_planes(
        layer: &CompressedLayer,
        planes: &[BitVecF2],
    ) -> Result<Self, String> {
        let n_w = layer.dtype.bits();
        let n = layer.n_weights();
        if planes.len() != n_w {
            return Err(format!(
                "layer {:?}: {} planes for dtype {:?} (want {n_w})",
                layer.name,
                planes.len(),
                layer.dtype
            ));
        }
        if layer.mask.len() != n {
            return Err(format!(
                "layer {:?}: mask has {} bits for {n} weights",
                layer.name,
                layer.mask.len()
            ));
        }
        for (k, p) in planes.iter().enumerate() {
            if p.len() != n {
                return Err(format!(
                    "layer {:?}: plane {k} has {} bits for {n} weights",
                    layer.name,
                    p.len()
                ));
            }
        }
        let wpr = layer.cols.div_ceil(64);
        let mut plane_words = Vec::with_capacity(n_w * layer.rows * wpr);
        for p in planes {
            pack_rows(p, layer.rows, layer.cols, &mut plane_words);
        }
        let mut mask_words = Vec::with_capacity(layer.rows * wpr);
        pack_rows(&layer.mask, layer.rows, layer.cols, &mut mask_words);
        FusedLayer::from_raw(
            layer.rows,
            layer.cols,
            layer.dtype,
            layer.scale,
            plane_words,
            mask_words,
        )
    }

    /// Rebuild from already-row-padded words (the IPC wire path).
    /// Word counts are validated against the geometry; stray bits past
    /// `cols` in a row's tail word are never read, so hostile padding
    /// is harmless.
    pub fn from_raw(
        rows: usize,
        cols: usize,
        dtype: Dtype,
        scale: f32,
        planes: Vec<u64>,
        mask: Vec<u64>,
    ) -> Result<Self, String> {
        let n_w = dtype.bits();
        let wpr = cols.div_ceil(64);
        let stride = rows
            .checked_mul(wpr)
            .ok_or("fused layer shape overflows")?;
        let want = stride
            .checked_mul(n_w)
            .ok_or("fused layer shape overflows")?;
        if planes.len() != want {
            return Err(format!(
                "fused layer has {} plane words for {rows}×{cols} {dtype:?} \
                 (want {want})",
                planes.len()
            ));
        }
        if mask.len() != stride {
            return Err(format!(
                "fused layer has {} mask words for {rows}×{cols} \
                 (want {stride})",
                mask.len()
            ));
        }
        Ok(FusedLayer {
            rows,
            cols,
            dtype,
            scale,
            words_per_row: wpr,
            planes,
            mask,
        })
    }

    /// Output dimension.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Input dimension.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Weight dtype.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// INT8 dequantization scale (1.0 for F32).
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Words per row-padded row (`⌈cols/64⌉`).
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// Concatenated plane words (plane-major, row-padded), for the wire.
    pub fn plane_words(&self) -> &[u64] {
        &self.planes
    }

    /// Row-padded mask words, for the wire.
    pub fn mask_words(&self) -> &[u64] {
        &self.mask
    }

    /// Resident bytes: `(n_w + 1) · rows · ⌈cols/64⌉ · 8` — what this
    /// layer costs a [`crate::store::ModelStore`] cache budget.
    pub fn planned_bytes(&self) -> usize {
        (self.planes.len() + self.mask.len())
            * std::mem::size_of::<u64>()
    }

    /// Decode the 64-weight group at row `r`, word `w` into
    /// `buf[..lim]`; returns `lim` (64, or the tail width).
    #[inline]
    fn decode_group(
        &self,
        r: usize,
        w: usize,
        lanes: &mut [u64; 64],
        buf: &mut [f32; 64],
    ) -> usize {
        let n_w = self.dtype.bits();
        let stride = self.rows * self.words_per_row;
        let row_off = r * self.words_per_row + w;
        // Lane `k` carries weight bit `k` = plane `n_w − 1 − k`
        // (MSB-first planes); after the transpose, `lanes[c]`'s low
        // `n_w` bits are weight `w·64 + c`'s bit pattern.
        for (k, lane) in lanes.iter_mut().take(n_w).enumerate() {
            *lane = self.planes[(n_w - 1 - k) * stride + row_off];
        }
        for lane in lanes.iter_mut().skip(n_w) {
            *lane = 0;
        }
        transpose64(lanes);
        let m = self.mask[row_off];
        let lim = 64.min(self.cols - w * 64);
        match self.dtype {
            Dtype::F32 => {
                for (c, slot) in buf.iter_mut().take(lim).enumerate() {
                    *slot = if (m >> c) & 1 == 1 {
                        f32::from_bits(lanes[c] as u32)
                    } else {
                        0.0
                    };
                }
            }
            Dtype::I8 => {
                for (c, slot) in buf.iter_mut().take(lim).enumerate() {
                    // Pruned weights are literal +0.0, never `0·scale`:
                    // a negative scale would yield −0.0 and break
                    // bit-exactness with the materialized path.
                    *slot = if (m >> c) & 1 == 1 {
                        (lanes[c] as u8 as i8) as f32 * self.scale
                    } else {
                        0.0
                    };
                }
            }
        }
        lim
    }

    /// `y = W·x` decoded on the fly, identical accumulation order
    /// (ascending column, pruned terms included as `+0.0`) to
    /// [`DecodedLayer::gemv`] — bit-exact with the materialized path.
    pub fn gemv(&self, x: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.gemv_into(x, &mut out);
        out
    }

    /// [`FusedLayer::gemv`] into a caller-owned buffer (cleared and
    /// refilled), so batch loops reuse allocations.
    pub fn gemv_into(&self, x: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(self.cols, x.len());
        out.clear();
        out.reserve(self.rows);
        let mut lanes = [0u64; 64];
        let mut wbuf = [0f32; 64];
        for r in 0..self.rows {
            let mut acc = 0.0f32;
            for w in 0..self.words_per_row {
                let lim = self.decode_group(r, w, &mut lanes, &mut wbuf);
                // Truncate like the materialized zip if `x` is short
                // (callers validate lengths at the serving boundary).
                let xs = x.get(w * 64..).unwrap_or(&[]);
                for (wt, &xv) in wbuf.iter().take(lim).zip(xs) {
                    acc += wt * xv;
                }
            }
            out.push(acc);
        }
    }

    /// Materialize the dense layer (bit-exact with the weights the
    /// materialized decode path produces) — for tests, tooling, and
    /// callers that need raw weights.
    pub fn to_dense(&self) -> DecodedLayer {
        let mut weights = Vec::with_capacity(self.rows * self.cols);
        let mut lanes = [0u64; 64];
        let mut wbuf = [0f32; 64];
        for r in 0..self.rows {
            for w in 0..self.words_per_row {
                let lim = self.decode_group(r, w, &mut lanes, &mut wbuf);
                weights.extend_from_slice(&wbuf[..lim]);
            }
        }
        DecodedLayer { rows: self.rows, cols: self.cols, weights }
    }
}

/// Repack a flat `rows·cols`-bit vector row-padded: each row restarts
/// on a word boundary so unaligned rows (`cols % 64 != 0`) become
/// whole-word reads. `BitVecF2::block` zero-pads tail reads.
fn pack_rows(bits: &BitVecF2, rows: usize, cols: usize, out: &mut Vec<u64>) {
    let wpr = cols.div_ceil(64);
    for r in 0..rows {
        for w in 0..wpr {
            let width = 64.min(cols - w * 64);
            out.push(bits.block(r * cols + w * 64, width) as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
    use crate::pipeline::{CompressionConfig, Compressor};
    use crate::rng::Rng;
    use crate::sparse::decode_plane_with;
    use crate::{decoder::SequentialDecoder, kernels::KernelKind};

    fn compress(rows: usize, cols: usize, seed: u64) -> CompressedLayer {
        let spec = LayerSpec { name: "t".into(), rows, cols };
        let layer = SyntheticLayer::generate(&spec, WeightGen::default(), seed);
        let (q, scale) = quantize_i8(&layer.weights);
        let cfg = CompressionConfig {
            sparsity: 0.75,
            n_s: 0,
            ..Default::default()
        };
        let (cl, _) =
            Compressor::new(cfg).compress_i8("t", rows, cols, &q, scale);
        cl
    }

    fn decoded_planes(cl: &CompressedLayer) -> Vec<BitVecF2> {
        let dec = SequentialDecoder::random(cl.spec, cl.m_seed);
        (0..cl.planes.len())
            .map(|k| decode_plane_with(cl, &dec, k, KernelKind::Word))
            .collect()
    }

    #[test]
    fn fused_dense_and_gemv_match_materialized_bit_exact() {
        // Unaligned cols (37, 64+13) exercise the row-padded tail.
        for (rows, cols, seed) in [(5, 37, 1u64), (8, 77, 2), (3, 64, 3)] {
            let cl = compress(rows, cols, seed);
            let planes = decoded_planes(&cl);
            let fused = FusedLayer::from_planes(&cl, &planes).unwrap();
            let dense = DecodedLayer::from_compressed(&cl);
            assert_eq!(fused.to_dense().weights, dense.weights);
            let mut rng = Rng::new(seed);
            let x: Vec<f32> =
                (0..cols).map(|_| rng.next_f32() - 0.5).collect();
            let got = fused.gemv(&x);
            let want = dense.gemv(&x);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "{rows}x{cols}");
            }
        }
    }

    #[test]
    fn from_planes_rejects_malformed_shapes() {
        let cl = compress(4, 20, 9);
        let planes = decoded_planes(&cl);
        assert!(FusedLayer::from_planes(&cl, &planes[..7]).is_err());
        let mut short = planes.clone();
        short[3] = BitVecF2::zeros(10);
        assert!(FusedLayer::from_planes(&cl, &short).is_err());
    }

    #[test]
    fn from_raw_validates_word_counts() {
        assert!(FusedLayer::from_raw(
            2,
            70,
            Dtype::I8,
            1.0,
            vec![0; 8 * 2 * 2],
            vec![0; 2 * 2]
        )
        .is_ok());
        assert!(FusedLayer::from_raw(
            2,
            70,
            Dtype::I8,
            1.0,
            vec![0; 8 * 2 * 2 - 1],
            vec![0; 2 * 2]
        )
        .is_err());
        assert!(FusedLayer::from_raw(
            2,
            70,
            Dtype::I8,
            1.0,
            vec![0; 8 * 2 * 2],
            vec![0; 5]
        )
        .is_err());
    }

    #[test]
    fn planned_bytes_is_planes_plus_mask_words() {
        let cl = compress(4, 70, 5);
        let planes = decoded_planes(&cl);
        let fused = FusedLayer::from_planes(&cl, &planes).unwrap();
        // 8 planes + 1 mask, 4 rows × 2 words/row, 8 bytes each.
        assert_eq!(fused.planned_bytes(), 9 * 4 * 2 * 8);
        assert!(fused.planned_bytes() < 4 * 70 * 4, "I8 fused < dense");
    }
}

//! 64×64 bit-matrix transpose and word-level plane reassembly.
//!
//! Reassembling one weight used to probe all `n_w` planes through
//! `BitVecF2::get` — `n_w` shifted loads per weight. But 64 consecutive
//! weights' bits live in one `u64` word per plane, so a 64×64 bit-matrix
//! transpose turns `n_w` plane words into 64 ready weight bit patterns
//! in 6 delta-swap stages of word-wide XORs (~6·64 word ops for 64·`n_w`
//! bits — the software analogue of the paper's parallel XOR array).

use crate::gf2::BitVecF2;

/// In-place 64×64 bit-matrix transpose (Hacker's Delight §7-3 delta
/// swaps): after the call, bit `r` of `a[c]` equals bit `c` of the
/// original `a[r]`.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j = 32usize;
    let mut m: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k = 0usize;
        while k < 64 {
            let t = ((a[k] >> j) ^ a[k + j]) & m;
            a[k] ^= t << j;
            a[k + j] ^= t;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        m ^= m << j;
    }
}

/// Load transpose input for word `wi`: lane `r` carries weight bit `r`,
/// i.e. plane `n_w − 1 − r` (planes are MSB-first); unused lanes zero.
/// After [`transpose64`], `lanes[c]`'s low `n_w` bits are weight
/// `wi·64 + c`'s bit pattern.
#[inline]
fn load_lanes(planes: &[BitVecF2], n_w: usize, wi: usize, lanes: &mut [u64; 64]) {
    for (r, lane) in lanes.iter_mut().take(n_w).enumerate() {
        *lane = planes[n_w - 1 - r].words()[wi];
    }
    for lane in lanes.iter_mut().skip(n_w) {
        *lane = 0;
    }
}

/// Word-level f32 reassembly under the word-masked prune gate. Callers
/// (the fallible `assemble`) validate `planes.len() == 32` and per-plane
/// lengths before dispatching here.
pub(crate) fn reassemble_f32_words(
    planes: &[BitVecF2],
    mask: &BitVecF2,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(planes.len(), 32);
    debug_assert_eq!(mask.len(), n);
    let mut out = Vec::with_capacity(n);
    let mut lanes = [0u64; 64];
    for wi in 0..n.div_ceil(64) {
        load_lanes(planes, 32, wi, &mut lanes);
        transpose64(&mut lanes);
        let m = mask.words()[wi];
        let lim = 64.min(n - wi * 64);
        for c in 0..lim {
            // Pruned positions decode to arbitrary bits; the mask word
            // gates them to the same +0.0 the scalar path returns.
            out.push(if (m >> c) & 1 == 1 {
                f32::from_bits(lanes[c] as u32)
            } else {
                0.0
            });
        }
    }
    out
}

/// Word-level i8 reassembly (dequantized by `scale`); same contract as
/// [`reassemble_f32_words`] with `planes.len() == 8`.
pub(crate) fn reassemble_i8_words(
    planes: &[BitVecF2],
    mask: &BitVecF2,
    n: usize,
    scale: f32,
) -> Vec<f32> {
    debug_assert_eq!(planes.len(), 8);
    debug_assert_eq!(mask.len(), n);
    let mut out = Vec::with_capacity(n);
    let mut lanes = [0u64; 64];
    for wi in 0..n.div_ceil(64) {
        load_lanes(planes, 8, wi, &mut lanes);
        transpose64(&mut lanes);
        let m = mask.words()[wi];
        let lim = 64.min(n - wi * 64);
        for c in 0..lim {
            // Pruned weights must be literal +0.0, not `0 · scale`: a
            // negative scale would yield −0.0 and break bit-exactness
            // with the scalar path.
            out.push(if (m >> c) & 1 == 1 {
                (lanes[c] as u8 as i8) as f32 * scale
            } else {
                0.0
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn transpose_is_exact() {
        let mut rng = Rng::new(11);
        let mut a = [0u64; 64];
        for lane in a.iter_mut() {
            *lane = rng.next_u64();
        }
        let orig = a;
        transpose64(&mut a);
        for r in 0..64 {
            for c in 0..64 {
                assert_eq!((a[c] >> r) & 1, (orig[r] >> c) & 1, "({r},{c})");
            }
        }
    }

    #[test]
    fn transpose_twice_is_identity() {
        let mut rng = Rng::new(12);
        let mut a = [0u64; 64];
        for lane in a.iter_mut() {
            *lane = rng.next_u64();
        }
        let orig = a;
        transpose64(&mut a);
        transpose64(&mut a);
        assert_eq!(a, orig);
    }

    /// Build MSB-first planes from raw weight bit patterns, like the
    /// compression pipeline does.
    fn planes_from_bits(bits: &[u64], n_w: usize) -> Vec<BitVecF2> {
        (0..n_w)
            .map(|k| {
                BitVecF2::from_iter_bits(
                    bits.iter().map(|&b| (b >> (n_w - 1 - k)) & 1 == 1),
                )
            })
            .collect()
    }

    #[test]
    fn f32_words_matches_per_weight_probe_with_tail() {
        let mut rng = Rng::new(13);
        for n in [1usize, 63, 64, 65, 130, 200] {
            let bits: Vec<u64> =
                (0..n).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect();
            let planes = planes_from_bits(&bits, 32);
            let mask =
                BitVecF2::from_iter_bits((0..n).map(|_| rng.bernoulli(0.7)));
            let got = reassemble_f32_words(&planes, &mask, n);
            for (i, &g) in got.iter().enumerate() {
                let want = if mask.get(i) {
                    f32::from_bits(bits[i] as u32)
                } else {
                    0.0
                };
                assert_eq!(g.to_bits(), want.to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn i8_words_matches_per_weight_probe() {
        let mut rng = Rng::new(14);
        let n = 150;
        let bits: Vec<u64> = (0..n).map(|_| rng.next_u64() & 0xFF).collect();
        let planes = planes_from_bits(&bits, 8);
        let mask =
            BitVecF2::from_iter_bits((0..n).map(|_| rng.bernoulli(0.5)));
        for scale in [0.5f32, -0.25] {
            let got = reassemble_i8_words(&planes, &mask, n, scale);
            for (i, &g) in got.iter().enumerate() {
                let want = if mask.get(i) {
                    (bits[i] as u8 as i8) as f32 * scale
                } else {
                    0.0
                };
                assert_eq!(g.to_bits(), want.to_bits(), "scale={scale} i={i}");
            }
        }
    }
}

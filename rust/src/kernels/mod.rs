//! Word-parallel bit-plane kernels and the fused decode→GEMV path.
//!
//! The paper's fixed-to-fixed format keeps every access fixed-size and
//! unit-stride — the property irregular formats like CSR destroy — yet
//! the original hot loops squandered it: `decode_stream_to_bits` wrote
//! one bit per iteration, `reassemble_*` probed all `n_w` planes per
//! weight through `BitVecF2::get`, and serving always round-tripped
//! through a fully materialized dense buffer. This module rebuilds
//! those loops over the `u64` words `BitVecF2` already stores:
//!
//! * [`BlockWriter`] — appends decoded `N_out ≤ 128`-bit blocks
//!   directly into `u64` words (≤ 3 shift/OR ops per block instead of
//!   `N_out` per-bit stores);
//! * [`transpose64`] — the 64×64 bit-matrix transpose (delta-swap
//!   network): one call turns `n_w` plane words into 64 ready weight
//!   bit patterns, so reassembly costs ~6 word ops per plane word
//!   instead of 64 single-bit probes;
//! * [`FusedLayer`] — executes `y = W·x` directly from bit-planes +
//!   mask, never materializing the dense f32 buffer, shrinking the
//!   resident footprint of I8 layers to ~9/32 of dense (relieving
//!   eviction pressure, `Auto` readahead admission, and IPC transfer
//!   size alike);
//! * [`ExecLayer`] — the store's cache value: a layer in whichever
//!   representation its [`DecodeMode`] picked, behind one
//!   `gemv`/`gemv_into` surface so backends and routers don't care.
//!
//! **Kernel selection** is a runtime switch ([`KernelKind::active`]):
//! the word-parallel path is the default; `F2F_KERNEL=scalar` forces
//! the portable per-bit fallback (and `benches/store.rs` times both as
//! `decode_kernel_scalar` vs `decode_kernel_word`). There are no
//! hand-written SIMD intrinsics by design — the `u64` bit ops and
//! `count_ones` lanes autovectorize on every target, and the f32
//! accumulation is kept strictly sequential because reordering it
//! would break the bit-exactness contract between scalar, word, and
//! fused paths that `rust/tests/fused_parity.rs` pins down.

mod fused;
mod transpose;
mod writer;

pub use fused::FusedLayer;
pub use transpose::transpose64;
pub(crate) use transpose::{reassemble_f32_words, reassemble_i8_words};
pub use writer::BlockWriter;

use crate::container::CompressedLayer;
use crate::gf2::BitVecF2;
use crate::sparse::DecodedLayer;

/// Which inner-loop implementation the decode/reassemble hot paths use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable per-bit reference loops (the original paths).
    Scalar,
    /// `u64`-word blocked loops (block writer + bit-matrix transpose).
    Word,
}

impl KernelKind {
    /// The process-wide kernel, resolved once: `Word` unless the
    /// environment forces the fallback with `F2F_KERNEL=scalar`.
    pub fn active() -> KernelKind {
        static ACTIVE: std::sync::OnceLock<KernelKind> =
            std::sync::OnceLock::new();
        *ACTIVE.get_or_init(|| {
            KernelKind::from_env(std::env::var("F2F_KERNEL").ok().as_deref())
        })
    }

    /// Pure mapping from the `F2F_KERNEL` value (testable without
    /// mutating process environment).
    pub(crate) fn from_env(v: Option<&str>) -> KernelKind {
        match v {
            Some("scalar") => KernelKind::Scalar,
            _ => KernelKind::Word,
        }
    }
}

/// How a store turns a compressed layer into an executable one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodeMode {
    /// Decode to the dense f32 buffer (the original path).
    #[default]
    Materialized,
    /// Keep decoded bit-planes resident; GEMV decodes on the fly.
    Fused,
    /// Per layer, whichever representation is smaller resident —
    /// priced from the same geometry the cost table and the index
    /// expose, so cache accounting and readahead admission agree with
    /// the decision.
    Auto,
}

impl DecodeMode {
    /// Resolve `Auto` for one layer's geometry (`n_w` = bits per
    /// weight): fused wins iff its resident bytes undercut the dense
    /// buffer — true for I8 (9 plane-bits vs 32 dense bits per
    /// weight), false for F32 (33/32).
    pub fn resolve(self, rows: usize, cols: usize, n_w: usize) -> DecodeMode {
        match self {
            DecodeMode::Auto => {
                if fused_bytes(rows, cols, n_w) < dense_bytes(rows, cols) {
                    DecodeMode::Fused
                } else {
                    DecodeMode::Materialized
                }
            }
            m => m,
        }
    }

    /// Resident bytes a layer decoded under this mode will charge the
    /// cache budget — the *planned* size used for admission before the
    /// decode runs (and matching what `ExecLayer::planned_bytes`
    /// reports after).
    pub fn planned_bytes(self, rows: usize, cols: usize, n_w: usize) -> usize {
        match self.resolve(rows, cols, n_w) {
            DecodeMode::Fused => fused_bytes(rows, cols, n_w),
            _ => dense_bytes(rows, cols),
        }
    }
}

impl std::str::FromStr for DecodeMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "materialized" => Ok(DecodeMode::Materialized),
            "fused" => Ok(DecodeMode::Fused),
            "auto" => Ok(DecodeMode::Auto),
            other => Err(format!(
                "unknown decode mode {other:?} \
                 (expected materialized|fused|auto)"
            )),
        }
    }
}

impl std::fmt::Display for DecodeMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            DecodeMode::Materialized => "materialized",
            DecodeMode::Fused => "fused",
            DecodeMode::Auto => "auto",
        })
    }
}

/// Resident bytes of a fused layer: `n_w` planes + 1 mask, row-padded
/// to whole words (`(n_w + 1) · rows · ⌈cols/64⌉ · 8`).
pub fn fused_bytes(rows: usize, cols: usize, n_w: usize) -> usize {
    (n_w + 1)
        .saturating_mul(rows)
        .saturating_mul(cols.div_ceil(64))
        .saturating_mul(8)
}

/// Resident bytes of a materialized layer (`4·rows·cols`).
pub fn dense_bytes(rows: usize, cols: usize) -> usize {
    rows.saturating_mul(cols)
        .saturating_mul(std::mem::size_of::<f32>())
}

/// A decoded layer in whichever representation its decode mode picked.
/// This is what a [`crate::store::ModelStore`] caches and what the
/// serving GEMV loops execute against.
#[derive(Debug, Clone)]
pub enum ExecLayer {
    /// Dense f32 weights (the original representation).
    Materialized(DecodedLayer),
    /// Bit-planes + mask, decoded on the fly during GEMV.
    Fused(FusedLayer),
}

impl ExecLayer {
    /// Output dimension.
    pub fn rows(&self) -> usize {
        match self {
            ExecLayer::Materialized(l) => l.rows,
            ExecLayer::Fused(l) => l.rows(),
        }
    }

    /// Input dimension.
    pub fn cols(&self) -> usize {
        match self {
            ExecLayer::Materialized(l) => l.cols,
            ExecLayer::Fused(l) => l.cols(),
        }
    }

    /// True for the fused (bit-plane-resident) representation.
    pub fn is_fused(&self) -> bool {
        matches!(self, ExecLayer::Fused(_))
    }

    /// Resident bytes this layer charges a store's cache budget.
    pub fn planned_bytes(&self) -> usize {
        match self {
            ExecLayer::Materialized(l) => l.decoded_bytes(),
            ExecLayer::Fused(l) => l.planned_bytes(),
        }
    }

    /// `y = W·x`; both representations produce bit-identical outputs.
    pub fn gemv(&self, x: &[f32]) -> Vec<f32> {
        match self {
            ExecLayer::Materialized(l) => l.gemv(x),
            ExecLayer::Fused(l) => l.gemv(x),
        }
    }

    /// [`ExecLayer::gemv`] into a caller-owned buffer (cleared and
    /// refilled), so batch loops reuse allocations.
    pub fn gemv_into(&self, x: &[f32], out: &mut Vec<f32>) {
        match self {
            ExecLayer::Materialized(l) => l.gemv_into(x, out),
            ExecLayer::Fused(l) => l.gemv_into(x, out),
        }
    }

    /// The dense layer, cloned (materialized) or decoded (fused) —
    /// both bit-exact with the materialized decode path.
    pub fn to_decoded(&self) -> DecodedLayer {
        match self {
            ExecLayer::Materialized(l) => l.clone(),
            ExecLayer::Fused(l) => l.to_dense(),
        }
    }

    /// Dense row-major weights regardless of representation.
    pub fn dense_weights(&self) -> Vec<f32> {
        match self {
            ExecLayer::Materialized(l) => l.weights.clone(),
            ExecLayer::Fused(l) => l.to_dense().weights,
        }
    }

    /// The dense representation, if that is what's resident.
    pub fn as_materialized(&self) -> Option<&DecodedLayer> {
        match self {
            ExecLayer::Materialized(l) => Some(l),
            ExecLayer::Fused(_) => None,
        }
    }
}

/// Assemble decoded planes into the representation `mode` picks for
/// this layer's geometry. The decode pipeline's final step — fallible,
/// so malformed containers surface as decode errors, never panics.
pub(crate) fn assemble_exec(
    layer: &CompressedLayer,
    planes: &[BitVecF2],
    mode: DecodeMode,
) -> Result<ExecLayer, String> {
    match mode.resolve(layer.rows, layer.cols, layer.dtype.bits()) {
        DecodeMode::Fused => {
            FusedLayer::from_planes(layer, planes).map(ExecLayer::Fused)
        }
        _ => crate::sparse::assemble(layer, planes)
            .map(ExecLayer::Materialized),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_kind_env_mapping() {
        assert_eq!(KernelKind::from_env(None), KernelKind::Word);
        assert_eq!(KernelKind::from_env(Some("word")), KernelKind::Word);
        assert_eq!(KernelKind::from_env(Some("scalar")), KernelKind::Scalar);
    }

    #[test]
    fn decode_mode_parses_and_displays() {
        for s in ["materialized", "fused", "auto"] {
            let m: DecodeMode = s.parse().unwrap();
            assert_eq!(m.to_string(), s);
        }
        assert!("dense".parse::<DecodeMode>().is_err());
        assert_eq!(DecodeMode::default(), DecodeMode::Materialized);
    }

    #[test]
    fn auto_prices_i8_fused_and_f32_materialized() {
        // I8: 9 plane words vs 32 dense bytes per 64 weights → fused.
        assert_eq!(
            DecodeMode::Auto.resolve(16, 128, 8),
            DecodeMode::Fused
        );
        // F32: 33 words vs 32 words of dense bytes → materialized.
        assert_eq!(
            DecodeMode::Auto.resolve(16, 128, 32),
            DecodeMode::Materialized
        );
        // Fixed modes resolve to themselves.
        assert_eq!(
            DecodeMode::Fused.resolve(16, 128, 32),
            DecodeMode::Fused
        );
        assert_eq!(
            DecodeMode::Materialized.resolve(16, 128, 8),
            DecodeMode::Materialized
        );
    }

    #[test]
    fn planned_bytes_formulas() {
        // 3 rows × 70 cols I8: wpr = 2, (8+1)·3·2·8 = 432 fused.
        assert_eq!(fused_bytes(3, 70, 8), 432);
        assert_eq!(dense_bytes(3, 70), 840);
        assert_eq!(DecodeMode::Auto.planned_bytes(3, 70, 8), 432);
        assert_eq!(DecodeMode::Materialized.planned_bytes(3, 70, 8), 840);
        assert_eq!(DecodeMode::Auto.planned_bytes(3, 70, 32), 840);
    }
}

//! Word-parallel block writer: append decoded blocks straight into words.
//!
//! `SequentialDecoder::decode_stream_to_bits` used to lay each decoded
//! `N_out`-bit block down through `BitVecF2::set_block`, a per-bit
//! read-modify-write loop — `N_out` word stores per block. A decoded
//! block is already a bit-packed [`Block`], so writing it is three
//! shift/OR word operations at most (a 128-bit block at a nonzero word
//! offset spans three `u64` words). [`BlockWriter`] keeps a running bit
//! cursor and does exactly that.

use crate::gf2::{low_mask, BitVecF2, Block};

/// Appends `width ≤ 128`-bit blocks at a running cursor into `u64`
/// words; bits past the target length are dropped (the zero-padded tail
/// of the paper's `l = ⌈mn/N_out⌉` slicing).
#[derive(Debug)]
pub struct BlockWriter {
    words: Vec<u64>,
    n_bits: usize,
    cursor: usize,
}

impl BlockWriter {
    /// A writer for a vector of `n_bits` bits, cursor at bit 0.
    pub fn new(n_bits: usize) -> Self {
        BlockWriter { words: vec![0; n_bits.div_ceil(64)], n_bits, cursor: 0 }
    }

    /// True once `n_bits` bits have been written; further pushes no-op.
    pub fn is_full(&self) -> bool {
        self.cursor >= self.n_bits
    }

    /// Append the low `width ≤ 128` bits of `block` at the cursor.
    #[inline]
    pub fn push(&mut self, block: Block, width: usize) {
        debug_assert!(width <= 128);
        let width = width.min(self.n_bits - self.cursor);
        if width == 0 {
            return;
        }
        let b = block & low_mask(width);
        let (w, off) = (self.cursor / 64, self.cursor % 64);
        self.words[w] |= (b << off) as u64;
        // Bits spilling past word `w`: at most two more words
        // (`off ≤ 63`, `width ≤ 128`). The shift guard keeps the u128
        // shift amount in range (`off + width > 64` implies the shift
        // `64 - off` is at most 64, valid for a u128).
        let mut rem: Block = if off + width > 64 { b >> (64 - off) } else { 0 };
        let mut idx = w + 1;
        while rem != 0 {
            self.words[idx] |= rem as u64;
            rem >>= 64;
            idx += 1;
        }
        self.cursor += width;
    }

    /// Finish into a [`BitVecF2`] of the target length.
    pub fn finish(self) -> BitVecF2 {
        BitVecF2::from_words(self.words, self.n_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Reference writer: the original per-bit `set_block` path.
    fn reference(blocks: &[(Block, usize)], n_bits: usize) -> BitVecF2 {
        let mut v = BitVecF2::zeros(n_bits);
        let mut cursor = 0;
        for &(b, width) in blocks {
            if cursor >= n_bits {
                break;
            }
            let w = width.min(n_bits - cursor);
            v.set_block(cursor, w, b);
            cursor += w;
        }
        v
    }

    #[test]
    fn matches_per_bit_reference_across_widths_and_tails() {
        let mut rng = Rng::new(7);
        for n_out in [1usize, 3, 10, 12, 63, 64, 65, 80, 100, 127, 128] {
            for n_bits in [1usize, 63, 64, 65, 100, 1000, 1024, 4097] {
                let n_blocks = n_bits.div_ceil(n_out) + 2;
                let blocks: Vec<(Block, usize)> = (0..n_blocks)
                    .map(|_| {
                        let b = (rng.next_u64() as u128) << 64
                            | rng.next_u64() as u128;
                        (b, n_out)
                    })
                    .collect();
                let mut w = BlockWriter::new(n_bits);
                for &(b, width) in &blocks {
                    w.push(b, width);
                }
                assert_eq!(
                    w.finish(),
                    reference(&blocks, n_bits),
                    "n_out={n_out} n_bits={n_bits}"
                );
            }
        }
    }

    #[test]
    fn full_writer_drops_extra_blocks() {
        let mut w = BlockWriter::new(10);
        w.push(0x3FF, 10);
        assert!(w.is_full());
        w.push(!0, 128); // dropped
        let v = w.finish();
        assert_eq!(v.len(), 10);
        assert_eq!(v.count_ones(), 10);
    }

    #[test]
    fn zero_length_vector() {
        let mut w = BlockWriter::new(0);
        assert!(w.is_full());
        w.push(!0, 64);
        assert_eq!(w.finish().len(), 0);
    }
}

//! Serving metrics: counters + mergeable latency histograms.
//!
//! Latencies used to live in a raw sample reservoir, sorted on every
//! snapshot — O(n log n) per scrape, a hard sample ceiling, and a
//! subtle tail lie: percentile-by-index returned `Duration::ZERO` for
//! p99 of a one-sample window. [`crate::obs::HdrLite`] replaces that:
//! recording is O(1), snapshots are O(buckets), two windows merge
//! exactly (how per-worker metrics aggregate over the wire), and a
//! single-sample window reports that sample at every quantile. Two
//! histograms are kept: per-request end-to-end latency
//! (enqueue → response) and per-batch execution time — the request /
//! batch granularities of the `--metrics-out` registry (per-layer
//! lives in [`crate::store::StoreMetrics`]).

use crate::obs::HdrLite;
use crate::sync::lock_unpoisoned;
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics sink (interior mutability; cheap under one worker).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    completed: u64,
    batches: u64,
    batched_requests: u64,
    errors: u64,
    /// Per-request end-to-end latency (enqueue → response ready).
    latency: HdrLite,
    /// Per-batch forward execution time.
    batch_time: HdrLite,
}

/// Point-in-time copy of the metrics.
#[derive(Debug, Clone, Copy)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub errors: u64,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub max: Duration,
    /// Full per-request latency histogram (p50/p95/p99/max above are
    /// its quantiles; keep the histogram to merge or re-quantile).
    pub latency: HdrLite,
    /// Per-batch forward execution time histogram.
    pub batch_time: HdrLite,
}

impl Metrics {
    /// Record one executed batch: per-request end-to-end latencies
    /// plus the batch's forward execution wall time.
    pub fn record_batch(
        &self,
        latencies: &[Duration],
        batch_time: Duration,
    ) {
        let mut m = lock_unpoisoned(&self.inner);
        m.batches += 1;
        m.batched_requests += latencies.len() as u64;
        m.completed += latencies.len() as u64;
        for l in latencies {
            m.latency.record(*l);
        }
        m.batch_time.record(batch_time);
    }

    /// Record a failed request.
    pub fn record_error(&self) {
        lock_unpoisoned(&self.inner).errors += 1;
    }

    /// Snapshot with percentile computation (no sort — bucket walk).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = lock_unpoisoned(&self.inner);
        MetricsSnapshot {
            completed: m.completed,
            batches: m.batches,
            batched_requests: m.batched_requests,
            errors: m.errors,
            p50: m.latency.percentile(0.50),
            p95: m.latency.percentile(0.95),
            p99: m.latency.percentile(0.99),
            max: m.latency.max(),
            latency: m.latency,
            batch_time: m.batch_time,
        }
    }
}

impl MetricsSnapshot {
    /// Average requests per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn us(v: u64) -> Duration {
        Duration::from_micros(v)
    }

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.record_batch(&[us(100), us(200)], us(250));
        m.record_batch(&[us(300)], us(320));
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.completed, 3);
        assert_eq!(s.batches, 2);
        assert_eq!(s.errors, 1);
        assert!((s.mean_batch_size() - 1.5).abs() < 1e-12);
        // Histogram quantiles are bucket-resolution: within 2x of the
        // true sample, monotone, and exact at the max.
        assert!(s.p50 >= us(100) && s.p50 <= us(400), "p50={:?}", s.p50);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, us(300));
        assert_eq!(s.latency.count(), 3);
        assert_eq!(s.batch_time.count(), 2);
        assert_eq!(s.batch_time.max(), us(320));
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99, Duration::ZERO);
        assert_eq!(s.max, Duration::ZERO);
        assert!(s.latency.is_empty());
    }

    #[test]
    fn single_sample_window_has_nonzero_tail_percentiles() {
        // The old sort-by-index path returned ZERO for p99 of one
        // sample; the histogram reports the sample itself.
        let m = Metrics::default();
        m.record_batch(&[us(5_000)], us(5_100));
        let s = m.snapshot();
        assert_eq!(s.p50, us(5_000));
        assert_eq!(s.p95, us(5_000));
        assert_eq!(s.p99, us(5_000));
        assert_eq!(s.max, us(5_000));
    }

    #[test]
    fn two_sample_window_splits_body_and_tail() {
        let m = Metrics::default();
        m.record_batch(&[us(1_000), us(100_000)], us(101_000));
        let s = m.snapshot();
        assert!(s.p50 >= us(500) && s.p50 <= us(2_000), "p50={:?}", s.p50);
        assert_eq!(s.p99, us(100_000), "tail clamps to the exact max");
        assert_eq!(s.max, us(100_000));
    }

    #[test]
    fn skewed_window_keeps_percentiles_in_the_body() {
        // 99 fast requests + 1 outlier: p50/p99 stay near the body,
        // max reports the outlier exactly — the tail never hides.
        let m = Metrics::default();
        let fast = vec![us(1_000); 99];
        m.record_batch(&fast, us(99_000));
        m.record_batch(&[Duration::from_secs(1)], Duration::from_secs(1));
        let s = m.snapshot();
        assert!(s.p50 <= us(2_000), "p50={:?}", s.p50);
        assert!(s.p99 <= us(2_000), "p99 is the 99th of 100: {:?}", s.p99);
        assert_eq!(s.max, Duration::from_secs(1));
    }

    #[test]
    fn snapshots_merge_across_windows() {
        // Two sinks (e.g. two workers) merge into the same histogram
        // one sink recording everything would have produced.
        let a = Metrics::default();
        let b = Metrics::default();
        let both = Metrics::default();
        a.record_batch(&[us(100), us(200)], us(300));
        b.record_batch(&[us(50_000)], us(50_000));
        both.record_batch(&[us(100), us(200)], us(300));
        both.record_batch(&[us(50_000)], us(50_000));
        let mut merged = a.snapshot().latency;
        merged.merge(&b.snapshot().latency);
        assert_eq!(merged, both.snapshot().latency);
    }
}

//! Serving metrics: counters + latency reservoir.

use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics sink (interior mutability; cheap under one worker).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    completed: u64,
    batches: u64,
    batched_requests: u64,
    errors: u64,
    /// Latency samples in µs (bounded reservoir, newest kept).
    latencies_us: Vec<u64>,
}

const RESERVOIR: usize = 65_536;

/// Point-in-time copy of the metrics.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub errors: u64,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub max: Duration,
}

impl Metrics {
    /// Record one executed batch of `n` requests with per-request
    /// end-to-end latencies.
    pub fn record_batch(&self, latencies: &[Duration]) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.batched_requests += latencies.len() as u64;
        m.completed += latencies.len() as u64;
        for l in latencies {
            if m.latencies_us.len() >= RESERVOIR {
                let idx = (m.completed as usize) % RESERVOIR;
                m.latencies_us[idx] = l.as_micros() as u64;
            } else {
                m.latencies_us.push(l.as_micros() as u64);
            }
        }
    }

    /// Record a failed request.
    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Snapshot with percentile computation.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let mut ls = m.latencies_us.clone();
        ls.sort_unstable();
        let pick = |q: f64| -> Duration {
            if ls.is_empty() {
                Duration::ZERO
            } else {
                let idx = ((ls.len() as f64 * q) as usize).min(ls.len() - 1);
                Duration::from_micros(ls[idx])
            }
        };
        MetricsSnapshot {
            completed: m.completed,
            batches: m.batches,
            batched_requests: m.batched_requests,
            errors: m.errors,
            p50: pick(0.50),
            p95: pick(0.95),
            p99: pick(0.99),
            max: ls.last().copied().map(Duration::from_micros).unwrap_or_default(),
        }
    }
}

impl MetricsSnapshot {
    /// Average requests per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.record_batch(&[
            Duration::from_micros(100),
            Duration::from_micros(200),
        ]);
        m.record_batch(&[Duration::from_micros(300)]);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.completed, 3);
        assert_eq!(s.batches, 2);
        assert_eq!(s.errors, 1);
        assert!((s.mean_batch_size() - 1.5).abs() < 1e-12);
        assert_eq!(s.p50, Duration::from_micros(200));
        assert_eq!(s.max, Duration::from_micros(300));
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99, Duration::ZERO);
    }
}

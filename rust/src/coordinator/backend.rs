//! Execution backends for the inference server.

use crate::container::CompressedLayer;
use crate::sparse::DecodedLayer;
use anyhow::{bail, Result};

/// Something that can run a batch of mat-vec requests.
///
/// `&mut self` so backends may keep scratch buffers / device handles.
///
/// A backend may serve a single anonymous model (the original contract:
/// `forward_batch` + `input_dim`/`output_dim`) or several named ones
/// (a [`crate::registry::ModelRegistry`] zoo). The model-scoped methods
/// default to "no named models": single-model backends implement
/// nothing new, and the empty model id `""` always routes to the
/// anonymous path.
pub trait Backend {
    /// Compute `y_i = f(x_i)` for every request in the batch. Fallible:
    /// a store/decode failure is reported to the callers of the batch
    /// (the server keeps serving), never a panic in the worker.
    fn forward_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
    /// Expected input length.
    fn input_dim(&self) -> usize;
    /// Produced output length.
    fn output_dim(&self) -> usize;

    /// Named models this backend serves (empty for single-model
    /// backends). The server builds one metrics window per entry.
    fn models(&self) -> Vec<String> {
        Vec::new()
    }

    /// Run a batch against one named model. Every request in `xs`
    /// belongs to `model` — the server's batcher never mixes models in
    /// one batch. `""` is the anonymous single-model path.
    fn forward_model_batch(
        &mut self,
        model: &str,
        xs: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        if model.is_empty() {
            self.forward_batch(xs)
        } else {
            bail!("backend serves no model {model:?}")
        }
    }

    /// Input length of one named model (`""` = the anonymous model).
    fn model_input_dim(&self, model: &str) -> Option<usize> {
        if model.is_empty() {
            Some(self.input_dim())
        } else {
            None
        }
    }

    /// Output length of one named model (`""` = the anonymous model).
    fn model_output_dim(&self, model: &str) -> Option<usize> {
        if model.is_empty() {
            Some(self.output_dim())
        } else {
            None
        }
    }
}

/// Native Rust backend: decode the compressed layer once at startup
/// (exactly what the on-chip XOR decompressor does between memory and
/// compute), then serve batched GEMVs from the decoded weights.
///
/// Single-layer only — multi-layer models are served by
/// [`crate::store::ModelBackend`] over a budgeted
/// [`crate::store::ModelStore`].
pub struct NativeBackend {
    layer: DecodedLayer,
}

impl NativeBackend {
    /// Decode a compressed layer into a ready-to-serve backend.
    pub fn new(compressed: &CompressedLayer) -> Self {
        NativeBackend { layer: DecodedLayer::from_compressed(compressed) }
    }

    /// Wrap an already-decoded layer.
    pub fn from_decoded(layer: DecodedLayer) -> Self {
        NativeBackend { layer }
    }
}

impl Backend for NativeBackend {
    fn forward_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(xs.iter().map(|x| self.layer.gemv(x)).collect())
    }

    fn input_dim(&self) -> usize {
        self.layer.cols
    }

    fn output_dim(&self) -> usize {
        self.layer.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_matches_direct_gemv() {
        let layer = DecodedLayer {
            rows: 2,
            cols: 3,
            weights: vec![1.0, 0.0, -1.0, 0.5, 2.0, 0.0],
        };
        let mut b = NativeBackend::from_decoded(layer.clone());
        let xs = vec![vec![1.0, 2.0, 3.0], vec![0.0, 1.0, 0.0]];
        let ys = b.forward_batch(&xs).unwrap();
        assert_eq!(ys[0], layer.gemv(&xs[0]));
        assert_eq!(ys[1], vec![0.0, 2.0]);
        assert_eq!(b.input_dim(), 3);
        assert_eq!(b.output_dim(), 2);
    }
}

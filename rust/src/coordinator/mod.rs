//! Serving coordinator: request router → dynamic batcher → worker.
//!
//! The paper's decoder is a memory-path device; the serving story around
//! it is a standard inference server. This module provides a compact but
//! real one: callers submit vectors, a batcher groups them (size- and
//! deadline-bounded, vLLM-style), a worker thread executes the batch on a
//! [`Backend`] (native Rust decode+GEMV, or the PJRT executable built
//! from the JAX/Pallas layers), and metrics record throughput and
//! latency percentiles.
//!
//! PJRT handles are not `Send`, so the worker *constructs* its backend on
//! its own thread via a `Send` factory closure.
//!
//! Backends: [`NativeBackend`] serves one decoded layer; whole models go
//! through [`crate::store::ModelBackend`], which chains every layer of a
//! compressed container from a byte-budgeted
//! [`crate::store::ModelStore`]; split models go through
//! [`crate::shard::ShardRouter`], which routes the same chain across N
//! independent stores (bit-identical outputs, per-shard decode
//! services).

mod backend;
mod batcher;
mod metrics;
mod server;

pub use backend::{Backend, NativeBackend};
pub use batcher::{BatchPolicy, Batcher};
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{InferenceServer, ServerConfig};

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::{bail, Result};
    use std::time::Duration;

    /// Echo backend for plumbing tests.
    struct Echo;
    impl Backend for Echo {
        fn forward_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Ok(xs
                .iter()
                .map(|x| x.iter().map(|v| v * 2.0).collect())
                .collect())
        }
        fn input_dim(&self) -> usize {
            4
        }
        fn output_dim(&self) -> usize {
            4
        }
    }

    #[test]
    fn end_to_end_single_request() {
        let server = InferenceServer::start(
            ServerConfig::default(),
            || Box::new(Echo),
        )
        .unwrap();
        let y = server.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(y, vec![2.0, 4.0, 6.0, 8.0]);
        server.shutdown();
    }

    #[test]
    fn many_concurrent_requests_are_batched() {
        let cfg = ServerConfig {
            max_batch: 8,
            batch_timeout: Duration::from_millis(5),
            ..Default::default()
        };
        let server = InferenceServer::start(cfg, || Box::new(Echo)).unwrap();
        let handles: Vec<_> = (0..64)
            .map(|i| server.infer_async(vec![i as f32; 4]))
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let y = h.recv().unwrap().unwrap();
            assert_eq!(y[0], 2.0 * i as f32);
        }
        let m = server.metrics();
        assert_eq!(m.completed, 64);
        assert!(m.batches >= 8, "batches = {}", m.batches);
        assert!(
            m.mean_batch_size() > 1.0,
            "batching should group requests (mean {})",
            m.mean_batch_size()
        );
        server.shutdown();
    }

    #[test]
    fn multi_model_requests_batch_purely() {
        use std::sync::{Arc, Mutex};
        /// Two named models of different dims; logs every batch it
        /// executes so the test can check model purity.
        struct Zoo {
            log: Arc<Mutex<Vec<(String, usize)>>>,
        }
        impl Backend for Zoo {
            fn forward_batch(
                &mut self,
                _xs: &[Vec<f32>],
            ) -> Result<Vec<Vec<f32>>> {
                bail!("anonymous path unused")
            }
            fn input_dim(&self) -> usize {
                0
            }
            fn output_dim(&self) -> usize {
                0
            }
            fn models(&self) -> Vec<String> {
                vec!["a".into(), "b".into()]
            }
            fn model_input_dim(&self, model: &str) -> Option<usize> {
                match model {
                    "a" => Some(3),
                    "b" => Some(2),
                    _ => None,
                }
            }
            fn model_output_dim(&self, model: &str) -> Option<usize> {
                self.model_input_dim(model)
            }
            fn forward_model_batch(
                &mut self,
                model: &str,
                xs: &[Vec<f32>],
            ) -> Result<Vec<Vec<f32>>> {
                self.log
                    .lock()
                    .unwrap()
                    .push((model.to_string(), xs.len()));
                let gain = match model {
                    "a" => 2.0,
                    "b" => -1.0,
                    _ => bail!("no model {model:?}"),
                };
                Ok(xs
                    .iter()
                    .map(|x| x.iter().map(|v| v * gain).collect())
                    .collect())
            }
        }
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = log.clone();
        let server = InferenceServer::start(
            ServerConfig {
                max_batch: 4,
                batch_timeout: Duration::from_millis(5),
                ..Default::default()
            },
            move || Box::new(Zoo { log: log2 }),
        )
        .unwrap();
        assert_eq!(server.models(), vec!["a", "b"]);
        assert_eq!(server.model_input_dim("a"), Some(3));
        assert_eq!(server.model_input_dim("b"), Some(2));
        assert_eq!(server.model_input_dim("ghost"), None);
        // Interleave the two models; every reply must carry its own
        // model's transform even when enqueued back-to-back.
        let handles: Vec<_> = (0..16)
            .map(|i| {
                if i % 2 == 0 {
                    ("a", server.infer_model_async("a", vec![i as f32; 3]))
                } else {
                    ("b", server.infer_model_async("b", vec![i as f32; 2]))
                }
            })
            .collect();
        for (i, (model, h)) in handles.into_iter().enumerate() {
            let y = h.recv().unwrap().unwrap();
            let want = if model == "a" {
                2.0 * i as f32
            } else {
                -(i as f32)
            };
            assert_eq!(y[0], want, "request {i} on {model}");
        }
        // No batch ever mixed models (dims alone would explode), and
        // per-model windows saw exactly their own traffic.
        for (model, n) in log.lock().unwrap().iter() {
            assert!(model == "a" || model == "b");
            assert!(*n >= 1);
        }
        let ma = server.model_metrics("a").unwrap();
        let mb = server.model_metrics("b").unwrap();
        assert_eq!(ma.completed, 8);
        assert_eq!(mb.completed, 8);
        assert_eq!(server.metrics().completed, 16, "shared window sums");
        // Unknown models fail at submit, wrong dims fail per model.
        assert!(server.infer_model("ghost", vec![0.0; 3]).is_err());
        assert!(server.infer_model("a", vec![0.0; 2]).is_err());
        server.shutdown();
    }

    #[test]
    fn rejects_wrong_dimension() {
        let server = InferenceServer::start(
            ServerConfig::default(),
            || Box::new(Echo),
        )
        .unwrap();
        assert!(server.infer(vec![1.0; 3]).is_err());
        server.shutdown();
    }

    #[test]
    fn backend_errors_propagate_without_killing_the_worker() {
        /// Errors whenever the first element of any request is negative.
        struct Flaky;
        impl Backend for Flaky {
            fn forward_batch(
                &mut self,
                xs: &[Vec<f32>],
            ) -> Result<Vec<Vec<f32>>> {
                if xs.iter().any(|x| x[0] < 0.0) {
                    bail!("poisoned batch");
                }
                Ok(xs.to_vec())
            }
            fn input_dim(&self) -> usize {
                2
            }
            fn output_dim(&self) -> usize {
                2
            }
        }
        let server = InferenceServer::start(
            ServerConfig {
                max_batch: 1,
                batch_timeout: Duration::from_millis(1),
                ..Default::default()
            },
            || Box::new(Flaky),
        )
        .unwrap();
        assert_eq!(server.infer(vec![1.0, 2.0]).unwrap(), vec![1.0, 2.0]);
        let err = server.infer(vec![-1.0, 2.0]).unwrap_err();
        assert!(
            format!("{err:#}").contains("poisoned batch"),
            "caller sees the backend's error: {err:#}"
        );
        // The worker survived the failed batch and keeps serving.
        assert_eq!(server.infer(vec![3.0, 4.0]).unwrap(), vec![3.0, 4.0]);
        let m = server.metrics();
        assert_eq!(m.completed, 2);
        assert_eq!(m.errors, 1);
        server.shutdown();
    }
}

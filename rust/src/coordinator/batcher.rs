//! Dynamic batching policy.
//!
//! Classic size-or-deadline batching: a batch closes when it reaches
//! `max_batch` requests or when the oldest queued request has waited
//! `timeout`. This trades a bounded latency increment for the large
//! throughput win of batched execution (measured in
//! `benches/serving.rs`).

use std::time::{Duration, Instant};

/// Batch closing policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub timeout: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 16,
            timeout: Duration::from_millis(2),
        }
    }
}

/// Incremental batch builder (single consumer).
pub struct Batcher<T> {
    policy: BatchPolicy,
    pending: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    /// New batcher under `policy`.
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, pending: Vec::new(), oldest: None }
    }

    /// Add a request; returns a full batch if this push closed it.
    pub fn push(&mut self, item: T) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.push(item);
        if self.pending.len() >= self.policy.max_batch {
            self.take()
        } else {
            None
        }
    }

    /// Non-empty and the oldest entry has exceeded the deadline?
    pub fn expired(&self) -> bool {
        matches!(self.oldest, Some(t) if t.elapsed() >= self.policy.timeout)
    }

    /// How long the consumer may sleep before the deadline fires.
    pub fn time_left(&self) -> Option<Duration> {
        self.oldest.map(|t| {
            self.policy.timeout.saturating_sub(t.elapsed())
        })
    }

    /// Age of the oldest pending entry — how long the forming batch
    /// has been open (`None` when empty). Formation time is wait the
    /// *policy* chose to spend (size-or-deadline), distinct from the
    /// queue wait a full pipe imposes; the `batch_form` vs `queue`
    /// spans in [`crate::obs`] show them apart.
    pub fn oldest_age(&self) -> Option<Duration> {
        self.oldest.map(|t| t.elapsed())
    }

    /// The first pending entry, if any — lets the consumer decide
    /// whether an incoming request is batch-compatible (e.g. same
    /// model) before pushing, flushing first when it isn't.
    pub fn first(&self) -> Option<&T> {
        self.pending.first()
    }

    /// Close and return the current batch (None if empty).
    pub fn take(&mut self) -> Option<Vec<T>> {
        self.oldest = None;
        if self.pending.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut self.pending))
        }
    }

    /// Current queue length.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// Whether no requests are queued.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closes_at_max_batch() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 3,
            timeout: Duration::from_secs(10),
        });
        assert!(b.push(1).is_none());
        assert!(b.push(2).is_none());
        let batch = b.push(3).expect("batch closes at 3");
        assert_eq!(batch, vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_expiry() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            timeout: Duration::from_millis(1),
        });
        b.push(42);
        assert!(!b.expired() || b.time_left().unwrap() == Duration::ZERO);
        std::thread::sleep(Duration::from_millis(2));
        assert!(b.expired());
        assert_eq!(b.take().unwrap(), vec![42]);
    }

    #[test]
    fn first_peeks_without_consuming() {
        let mut b: Batcher<u32> = Batcher::new(BatchPolicy::default());
        assert!(b.first().is_none());
        b.push(7);
        b.push(8);
        assert_eq!(b.first(), Some(&7));
        assert_eq!(b.len(), 2, "peek must not consume");
        assert_eq!(b.take().unwrap(), vec![7, 8]);
        assert!(b.first().is_none());
    }

    #[test]
    fn take_on_empty_is_none() {
        let mut b: Batcher<u32> = Batcher::new(BatchPolicy::default());
        assert!(b.take().is_none());
        assert!(!b.expired());
    }

    #[test]
    fn oldest_age_tracks_the_forming_batch() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            timeout: Duration::from_secs(10),
        });
        assert!(b.oldest_age().is_none(), "empty batcher has no age");
        b.push(1);
        std::thread::sleep(Duration::from_millis(1));
        let age = b.oldest_age().expect("forming batch has an age");
        assert!(age >= Duration::from_millis(1));
        // Later pushes never reset the clock…
        b.push(2);
        assert!(b.oldest_age().unwrap() >= age);
        // …and taking the batch does.
        b.take();
        assert!(b.oldest_age().is_none());
    }
}

//! The inference server: one request queue, one batching worker thread.
//!
//! Tracing: every accepted request mints a trace id at enqueue
//! ([`crate::obs::mint_trace`]) and records an `enqueue` instant; at
//! execution the batch pins its *leader's* (first member's) trace to
//! the worker thread, so the forward pass — per-layer GEMV, decodes,
//! IPC fetches, however many hops away — stitches under one trace id.
//! Per-request `queue` spans and the `batch_form`/`batch` spans carry
//! each member's own id, so a batched request's wait is attributable
//! even when the execution spans hang off the leader.

use super::{Backend, BatchPolicy, Batcher, Metrics, MetricsSnapshot};
use crate::obs;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub batch_timeout: Duration,
    /// Bound on queued requests (backpressure: submit fails beyond it).
    pub queue_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 16,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 4096,
        }
    }
}

struct Request {
    x: Vec<f32>,
    enqueued: Instant,
    /// Trace id minted at enqueue; the batch leader's id is pinned to
    /// the worker thread for the forward pass.
    trace: u64,
    resp: Sender<Result<Vec<f32>>>,
}

/// Handle to a running server.
pub struct InferenceServer {
    tx: Sender<Request>,
    metrics: Arc<Metrics>,
    input_dim: usize,
    inflight: Arc<std::sync::atomic::AtomicUsize>,
    capacity: usize,
    stop: Arc<AtomicBool>,
    worker: Option<JoinHandle<()>>,
}

impl InferenceServer {
    /// Start a server; `factory` builds the backend *on the worker
    /// thread* (PJRT handles are not `Send`). Fails — instead of
    /// panicking the serving process — when the worker thread cannot
    /// be spawned or the backend dies during initialization.
    pub fn start<F>(config: ServerConfig, factory: F) -> Result<Self>
    where
        F: FnOnce() -> Box<dyn Backend> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let inflight = Arc::new(std::sync::atomic::AtomicUsize::new(0));

        // The worker reports its input dim back once the backend exists.
        let (dim_tx, dim_rx) = channel::<usize>();
        let m2 = metrics.clone();
        let s2 = stop.clone();
        let inf2 = inflight.clone();
        let worker = std::thread::Builder::new()
            .name("f2f-worker".into())
            .spawn(move || {
                let mut backend = factory();
                let _ = dim_tx.send(backend.input_dim());
                run_worker(rx, &mut *backend, &m2, &s2, &inf2, config);
            })
            .map_err(|e| anyhow!("spawn inference worker: {e}"))?;
        let input_dim =
            dim_rx.recv_timeout(Duration::from_secs(60)).map_err(|e| {
                anyhow!(
                    "backend failed to initialize: {}",
                    match e {
                        RecvTimeoutError::Timeout => "timed out",
                        RecvTimeoutError::Disconnected =>
                            "factory panicked or exited",
                    }
                )
            })?;

        Ok(InferenceServer {
            tx,
            metrics,
            input_dim,
            inflight,
            capacity: config.queue_capacity,
            stop,
            worker: Some(worker),
        })
    }

    /// Submit a request; returns a receiver for the response.
    pub fn infer_async(
        &self,
        x: Vec<f32>,
    ) -> Receiver<Result<Vec<f32>>> {
        let (resp_tx, resp_rx) = channel();
        if x.len() != self.input_dim {
            let _ = resp_tx.send(Err(anyhow!(
                "input dim {} != expected {}",
                x.len(),
                self.input_dim
            )));
            return resp_rx;
        }
        if self.inflight.load(Ordering::Relaxed) >= self.capacity {
            self.metrics.record_error();
            // Sheds are worth a journal line, but at queue-full rates
            // the journal's own limiter is what keeps this safe.
            obs::events::warn(
                "request_shed",
                "request shed: queue full (backpressure)",
                &[(
                    "capacity",
                    obs::events::Value::U64(self.capacity as u64),
                )],
            );
            let _ = resp_tx.send(Err(anyhow!("queue full (backpressure)")));
            return resp_rx;
        }
        self.inflight.fetch_add(1, Ordering::Relaxed);
        let trace = obs::mint_trace();
        obs::event_for(trace, obs::SpanKind::Enqueue, "");
        let req = Request {
            x,
            enqueued: Instant::now(),
            trace,
            resp: resp_tx.clone(),
        };
        if self.tx.send(req).is_err() {
            let _ = resp_tx.send(Err(anyhow!("server stopped")));
        }
        resp_rx
    }

    /// Blocking inference.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.infer_async(x)
            .recv()
            .map_err(|_| anyhow!("worker dropped response"))?
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shared handle to the live metrics — what the stats socket
    /// snapshots while the server keeps running.
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Current queue depth: requests accepted and not yet answered.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// The backpressure bound ([`ServerConfig::queue_capacity`]).
    pub fn queue_capacity(&self) -> usize {
        self.capacity
    }

    /// Shared handle to the inflight gauge, for live queue-depth
    /// sampling after the server handle has moved elsewhere.
    pub fn inflight_handle(&self) -> Arc<std::sync::atomic::AtomicUsize> {
        self.inflight.clone()
    }

    /// Expected input dimension.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Stop the worker and wait for it.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Drop sender so the worker's recv unblocks.
        let (dummy_tx, _) = channel();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

fn run_worker(
    rx: Receiver<Request>,
    backend: &mut dyn Backend,
    metrics: &Metrics,
    stop: &AtomicBool,
    inflight: &std::sync::atomic::AtomicUsize,
    config: ServerConfig,
) {
    let mut batcher = Batcher::new(BatchPolicy {
        max_batch: config.max_batch,
        timeout: config.batch_timeout,
    });
    loop {
        if stop.load(Ordering::Relaxed) && batcher.is_empty() {
            // Drain whatever is still queued, then exit.
            match rx.try_recv() {
                Ok(req) => {
                    if let Some(batch) = batcher.push(req) {
                        execute(backend, batch, metrics, inflight);
                    }
                    continue;
                }
                Err(_) => break,
            }
        }
        let wait = batcher
            .time_left()
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req) {
                    execute(backend, batch, metrics, inflight);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if batcher.expired() {
                    if let Some(batch) = batcher.take() {
                        execute(backend, batch, metrics, inflight);
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                if let Some(batch) = batcher.take() {
                    execute(backend, batch, metrics, inflight);
                }
                break;
            }
        }
    }
}

fn execute(
    backend: &mut dyn Backend,
    batch: Vec<Request>,
    metrics: &Metrics,
    inflight: &std::sync::atomic::AtomicUsize,
) {
    let Some(leader) = batch.first().map(|r| r.trace) else {
        return;
    };
    // Dequeue: each member's queue wait, plus the formation span
    // (oldest member's enqueue → batch closed) under the leader.
    for r in &batch {
        obs::span_for(
            r.trace,
            obs::SpanKind::Queue,
            "",
            r.enqueued.elapsed(),
        );
    }
    let oldest = batch
        .iter()
        .map(|r| r.enqueued.elapsed())
        .max()
        .unwrap_or_default();
    obs::span_for(leader, obs::SpanKind::BatchForm, "", oldest);
    // Pin the leader's trace for the forward pass: per-layer GEMV,
    // decode and IPC spans recorded below attach to it.
    let _trace = obs::with_trace(leader);
    let xs: Vec<Vec<f32>> = batch.iter().map(|r| r.x.clone()).collect();
    let started = Instant::now();
    match backend.forward_batch(&xs) {
        Ok(ys) => {
            let batch_time = started.elapsed();
            obs::span_for(leader, obs::SpanKind::Batch, "", batch_time);
            // Record metrics *before* releasing responses so a caller
            // that observed its reply always sees itself counted.
            let latencies: Vec<_> =
                batch.iter().map(|r| r.enqueued.elapsed()).collect();
            metrics.record_batch(&latencies, batch_time);
            for (req, y) in batch.into_iter().zip(ys) {
                inflight.fetch_sub(1, Ordering::Relaxed);
                let _ = req.resp.send(Ok(y));
            }
        }
        Err(e) => {
            // A failed batch fails its requests, not the process: every
            // caller gets the error, the worker keeps serving.
            let msg = format!("backend error: {e:#}");
            for req in batch {
                metrics.record_error();
                inflight.fetch_sub(1, Ordering::Relaxed);
                let _ = req.resp.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

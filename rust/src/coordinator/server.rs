//! The inference server: one request queue, one batching worker thread.
//!
//! Tracing: every accepted request mints a trace id at enqueue
//! ([`crate::obs::mint_trace`]) and records an `enqueue` instant; at
//! execution the batch pins its *leader's* (first member's) trace to
//! the worker thread, so the forward pass — per-layer GEMV, decodes,
//! IPC fetches, however many hops away — stitches under one trace id.
//! Per-request `queue` spans and the `batch_form`/`batch` spans carry
//! each member's own id, so a batched request's wait is attributable
//! even when the execution spans hang off the leader.

use super::{Backend, BatchPolicy, Batcher, Metrics, MetricsSnapshot};
use crate::obs;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub batch_timeout: Duration,
    /// Bound on queued requests (backpressure: submit fails beyond it).
    pub queue_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 16,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 4096,
        }
    }
}

struct Request {
    /// Model id this request targets (`""` = the anonymous
    /// single-model backend). Batches are model-pure: the worker
    /// flushes a forming batch before admitting a different model.
    model: String,
    x: Vec<f32>,
    enqueued: Instant,
    /// Trace id minted at enqueue; the batch leader's id is pinned to
    /// the worker thread for the forward pass.
    trace: u64,
    resp: Sender<Result<Vec<f32>>>,
}

/// One named model as the server fronts it: dims validated at submit,
/// plus a metrics window of its own (the shared [`Metrics`] still
/// aggregates across models).
#[derive(Clone)]
struct ModelPort {
    name: String,
    input_dim: usize,
    metrics: Arc<Metrics>,
}

/// Handle to a running server.
pub struct InferenceServer {
    tx: Sender<Request>,
    metrics: Arc<Metrics>,
    input_dim: usize,
    models: Vec<ModelPort>,
    inflight: Arc<std::sync::atomic::AtomicUsize>,
    capacity: usize,
    stop: Arc<AtomicBool>,
    worker: Option<JoinHandle<()>>,
}

impl InferenceServer {
    /// Start a server; `factory` builds the backend *on the worker
    /// thread* (PJRT handles are not `Send`). Fails — instead of
    /// panicking the serving process — when the worker thread cannot
    /// be spawned or the backend dies during initialization.
    pub fn start<F>(config: ServerConfig, factory: F) -> Result<Self>
    where
        F: FnOnce() -> Box<dyn Backend> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let inflight = Arc::new(std::sync::atomic::AtomicUsize::new(0));

        // The worker reports its input dim (and the named-model table,
        // for multi-model backends) back once the backend exists.
        let (dim_tx, dim_rx) = channel::<(usize, Vec<ModelPort>)>();
        let m2 = metrics.clone();
        let s2 = stop.clone();
        let inf2 = inflight.clone();
        let worker = std::thread::Builder::new()
            .name("f2f-worker".into())
            .spawn(move || {
                let mut backend = factory();
                let ports: Vec<ModelPort> = backend
                    .models()
                    .into_iter()
                    .filter_map(|name| {
                        let input_dim = backend.model_input_dim(&name)?;
                        Some(ModelPort {
                            name,
                            input_dim,
                            metrics: Arc::new(Metrics::default()),
                        })
                    })
                    .collect();
                let _ =
                    dim_tx.send((backend.input_dim(), ports.clone()));
                run_worker(
                    rx, &mut *backend, &m2, &ports, &s2, &inf2, config,
                );
            })
            .map_err(|e| anyhow!("spawn inference worker: {e}"))?;
        let (input_dim, models) =
            dim_rx.recv_timeout(Duration::from_secs(60)).map_err(|e| {
                anyhow!(
                    "backend failed to initialize: {}",
                    match e {
                        RecvTimeoutError::Timeout => "timed out",
                        RecvTimeoutError::Disconnected =>
                            "factory panicked or exited",
                    }
                )
            })?;

        Ok(InferenceServer {
            tx,
            metrics,
            input_dim,
            models,
            inflight,
            capacity: config.queue_capacity,
            stop,
            worker: Some(worker),
        })
    }

    /// Submit a request to the anonymous single-model backend; returns
    /// a receiver for the response.
    pub fn infer_async(
        &self,
        x: Vec<f32>,
    ) -> Receiver<Result<Vec<f32>>> {
        self.submit(String::new(), x, self.input_dim)
    }

    /// Submit a request to one named model of a multi-model backend.
    /// Dim validation is per model; an unknown model id fails at
    /// submit, before the queue.
    pub fn infer_model_async(
        &self,
        model: &str,
        x: Vec<f32>,
    ) -> Receiver<Result<Vec<f32>>> {
        if model.is_empty() {
            return self.infer_async(x);
        }
        let Some(port) = self.models.iter().find(|p| p.name == model)
        else {
            let (resp_tx, resp_rx) = channel();
            let _ = resp_tx
                .send(Err(anyhow!("unknown model {model:?}")));
            return resp_rx;
        };
        self.submit(model.to_string(), x, port.input_dim)
    }

    /// Blocking inference against one named model.
    pub fn infer_model(
        &self,
        model: &str,
        x: Vec<f32>,
    ) -> Result<Vec<f32>> {
        self.infer_model_async(model, x)
            .recv()
            .map_err(|_| anyhow!("worker dropped response"))?
    }

    fn submit(
        &self,
        model: String,
        x: Vec<f32>,
        expect_dim: usize,
    ) -> Receiver<Result<Vec<f32>>> {
        let (resp_tx, resp_rx) = channel();
        if x.len() != expect_dim {
            let _ = resp_tx.send(Err(anyhow!(
                "input dim {} != expected {}",
                x.len(),
                expect_dim
            )));
            return resp_rx;
        }
        if self.inflight.load(Ordering::Relaxed) >= self.capacity {
            self.metrics.record_error();
            // Sheds are worth a journal line, but at queue-full rates
            // the journal's own limiter is what keeps this safe.
            obs::events::warn(
                "request_shed",
                "request shed: queue full (backpressure)",
                &[(
                    "capacity",
                    obs::events::Value::U64(self.capacity as u64),
                )],
            );
            let _ = resp_tx.send(Err(anyhow!("queue full (backpressure)")));
            return resp_rx;
        }
        self.inflight.fetch_add(1, Ordering::Relaxed);
        let trace = obs::mint_trace();
        obs::event_for(trace, obs::SpanKind::Enqueue, &model);
        let req = Request {
            model,
            x,
            enqueued: Instant::now(),
            trace,
            resp: resp_tx.clone(),
        };
        if self.tx.send(req).is_err() {
            let _ = resp_tx.send(Err(anyhow!("server stopped")));
        }
        resp_rx
    }

    /// Blocking inference.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.infer_async(x)
            .recv()
            .map_err(|_| anyhow!("worker dropped response"))?
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shared handle to the live metrics — what the stats socket
    /// snapshots while the server keeps running.
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Named models the backend reported (empty for single-model
    /// backends), in the backend's order.
    pub fn models(&self) -> Vec<String> {
        self.models.iter().map(|p| p.name.clone()).collect()
    }

    /// Input dimension of one named model.
    pub fn model_input_dim(&self, model: &str) -> Option<usize> {
        self.models
            .iter()
            .find(|p| p.name == model)
            .map(|p| p.input_dim)
    }

    /// Metrics snapshot of one named model's window.
    pub fn model_metrics(&self, model: &str) -> Option<MetricsSnapshot> {
        self.models
            .iter()
            .find(|p| p.name == model)
            .map(|p| p.metrics.snapshot())
    }

    /// Shared handles to every named model's metrics window, for the
    /// stats socket to snapshot while the server keeps running.
    pub fn model_metrics_handles(&self) -> Vec<(String, Arc<Metrics>)> {
        self.models
            .iter()
            .map(|p| (p.name.clone(), p.metrics.clone()))
            .collect()
    }

    /// Current queue depth: requests accepted and not yet answered.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// The backpressure bound ([`ServerConfig::queue_capacity`]).
    pub fn queue_capacity(&self) -> usize {
        self.capacity
    }

    /// Shared handle to the inflight gauge, for live queue-depth
    /// sampling after the server handle has moved elsewhere.
    pub fn inflight_handle(&self) -> Arc<std::sync::atomic::AtomicUsize> {
        self.inflight.clone()
    }

    /// Expected input dimension.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Stop the worker and wait for it.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Drop sender so the worker's recv unblocks.
        let (dummy_tx, _) = channel();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

fn run_worker(
    rx: Receiver<Request>,
    backend: &mut dyn Backend,
    metrics: &Metrics,
    ports: &[ModelPort],
    stop: &AtomicBool,
    inflight: &std::sync::atomic::AtomicUsize,
    config: ServerConfig,
) {
    let mut batcher = Batcher::new(BatchPolicy {
        max_batch: config.max_batch,
        timeout: config.batch_timeout,
    });
    // Batches are model-pure: an incoming request for a different
    // model than the forming batch flushes the batch first (two
    // models' vectors generally don't even share a dimension).
    let mut admit =
        |batcher: &mut Batcher<Request>, req: Request, be: &mut dyn Backend| {
            if batcher.first().is_some_and(|p| p.model != req.model) {
                if let Some(batch) = batcher.take() {
                    execute(be, batch, metrics, ports, inflight);
                }
            }
            if let Some(batch) = batcher.push(req) {
                execute(be, batch, metrics, ports, inflight);
            }
        };
    loop {
        if stop.load(Ordering::Relaxed) && batcher.is_empty() {
            // Drain whatever is still queued, then exit.
            match rx.try_recv() {
                Ok(req) => {
                    admit(&mut batcher, req, backend);
                    continue;
                }
                Err(_) => break,
            }
        }
        let wait = batcher
            .time_left()
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(req) => {
                admit(&mut batcher, req, backend);
            }
            Err(RecvTimeoutError::Timeout) => {
                if batcher.expired() {
                    if let Some(batch) = batcher.take() {
                        execute(backend, batch, metrics, ports, inflight);
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                if let Some(batch) = batcher.take() {
                    execute(backend, batch, metrics, ports, inflight);
                }
                break;
            }
        }
    }
}

fn execute(
    backend: &mut dyn Backend,
    batch: Vec<Request>,
    metrics: &Metrics,
    ports: &[ModelPort],
    inflight: &std::sync::atomic::AtomicUsize,
) {
    let Some(leader) = batch.first().map(|r| r.trace) else {
        return;
    };
    // Model-pure by construction (see run_worker's admit): the
    // leader's model is the batch's model.
    let model = batch
        .first()
        .map(|r| r.model.clone())
        .unwrap_or_default();
    let model_metrics = ports
        .iter()
        .find(|p| p.name == model)
        .map(|p| p.metrics.as_ref());
    // Dequeue: each member's queue wait, plus the formation span
    // (oldest member's enqueue → batch closed) under the leader.
    for r in &batch {
        obs::span_for(
            r.trace,
            obs::SpanKind::Queue,
            "",
            r.enqueued.elapsed(),
        );
    }
    let oldest = batch
        .iter()
        .map(|r| r.enqueued.elapsed())
        .max()
        .unwrap_or_default();
    obs::span_for(leader, obs::SpanKind::BatchForm, "", oldest);
    // Pin the leader's trace for the forward pass: per-layer GEMV,
    // decode and IPC spans recorded below attach to it.
    let _trace = obs::with_trace(leader);
    let xs: Vec<Vec<f32>> = batch.iter().map(|r| r.x.clone()).collect();
    let started = Instant::now();
    match backend.forward_model_batch(&model, &xs) {
        Ok(ys) => {
            let batch_time = started.elapsed();
            obs::span_for(leader, obs::SpanKind::Batch, &model, batch_time);
            // Record metrics *before* releasing responses so a caller
            // that observed its reply always sees itself counted.
            let latencies: Vec<_> =
                batch.iter().map(|r| r.enqueued.elapsed()).collect();
            metrics.record_batch(&latencies, batch_time);
            if let Some(mm) = model_metrics {
                mm.record_batch(&latencies, batch_time);
            }
            for (req, y) in batch.into_iter().zip(ys) {
                inflight.fetch_sub(1, Ordering::Relaxed);
                let _ = req.resp.send(Ok(y));
            }
        }
        Err(e) => {
            // A failed batch fails its requests, not the process: every
            // caller gets the error, the worker keeps serving.
            let msg = format!("backend error: {e:#}");
            for req in batch {
                metrics.record_error();
                if let Some(mm) = model_metrics {
                    mm.record_error();
                }
                inflight.fetch_sub(1, Ordering::Relaxed);
                let _ = req.resp.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

//! Combinational (`N_s = 0`) encoder: independent exhaustive search per
//! block over all `2^{N_in}` decoder inputs (§3.1, the Kwon et al. 2020
//! baseline and the generator used for Figure 4's efficiency study).

use super::{diff_decoded, EncodeResult, Encoder, SlicedPlane};
use crate::decoder::SequentialDecoder;
use crate::encoder::EncodeStats;

/// Per-block exhaustive encoder. Requires `N_s = 0`.
#[derive(Debug, Clone)]
pub struct ExhaustiveEncoder {
    decoder: SequentialDecoder,
}

impl ExhaustiveEncoder {
    /// Wrap a combinational decoder.
    pub fn new(decoder: SequentialDecoder) -> Self {
        assert_eq!(
            decoder.spec().n_s,
            0,
            "ExhaustiveEncoder requires N_s = 0; use ViterbiEncoder"
        );
        ExhaustiveEncoder { decoder }
    }

    /// Best input for a single (data, mask) block: returns
    /// `(argmin input, min unmatched bits)`.
    pub fn encode_block(
        &self,
        data: crate::gf2::Block,
        mask: crate::gf2::Block,
    ) -> (u32, u32) {
        let table = self.decoder.tables().slot_table(0);
        let mut best = (0u32, u32::MAX);
        for (v, &out) in table.iter().enumerate() {
            let err = ((out ^ data) & mask).count_ones();
            if err < best.1 {
                best = (v as u32, err);
                if err == 0 {
                    break;
                }
            }
        }
        best
    }
}

impl Encoder for ExhaustiveEncoder {
    fn encode(&self, plane: &SlicedPlane) -> EncodeResult {
        assert_eq!(plane.n_out, self.decoder.spec().n_out);
        let mut encoded = Vec::with_capacity(plane.num_blocks());
        for t in 0..plane.num_blocks() {
            let (v, _) = self.encode_block(plane.data[t], plane.mask[t]);
            encoded.push(v);
        }
        let (matched, mismatches) =
            diff_decoded(&self.decoder, plane, &encoded);
        let unpruned = plane.unpruned_bits();
        let spec = self.decoder.spec();
        EncodeResult {
            stats: EncodeStats {
                total_bits: plane.num_blocks() * plane.n_out,
                unpruned_bits: unpruned,
                matched_bits: matched,
                error_bits: unpruned - matched,
                encoded_bits: spec.encoded_bits(plane.n_bits),
            },
            encoded,
            mismatches,
        }
    }

    fn decoder(&self) -> &SequentialDecoder {
        &self.decoder
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoder::DecoderSpec;
    use crate::gf2::BitVecF2;
    use crate::rng::Rng;

    #[test]
    fn finds_exact_match_when_target_is_decodable() {
        // Take a decoder output as data with full mask: error must be 0.
        let spec = DecoderSpec::new(8, 24, 0);
        let dec = SequentialDecoder::random(spec, 4);
        let enc = ExhaustiveEncoder::new(dec.clone());
        for v in [0u64, 17, 255] {
            let target = dec.matrix().decode(v);
            let (_, err) = enc.encode_block(target, crate::gf2::low_mask(24));
            assert_eq!(err, 0);
        }
    }

    #[test]
    fn unpruned_below_n_in_is_usually_free() {
        // With n_u ≤ N_in there are ≥ 2^{N_in - n_u} candidate inputs per
        // target on average; with a random code the match probability is
        // high (this is Fig. 4a's top-left regime).
        let spec = DecoderSpec::new(12, 24, 0);
        let dec = SequentialDecoder::random(spec, 9);
        let enc = ExhaustiveEncoder::new(dec.clone());
        let mut rng = Rng::new(5);
        let mut errs = 0u32;
        for _ in 0..50 {
            let data = BitVecF2::random(24, 0.5, &mut rng).block(0, 24);
            // exactly 6 unpruned bits
            let mut mask: u128 = 0;
            while mask.count_ones() < 6 {
                mask |= 1 << rng.below(24);
            }
            errs += enc.encode_block(data, mask).1;
        }
        assert_eq!(errs, 0, "n_u=6 ≪ N_in=12 should always match");
    }

    #[test]
    #[should_panic]
    fn rejects_sequential_decoder() {
        let spec = DecoderSpec::new(8, 24, 1);
        ExhaustiveEncoder::new(SequentialDecoder::random(spec, 1));
    }
}

//! Sliced bit-plane: the encoder's working form.
//!
//! §4 "Weight manipulation": a binary plane (one bit position of every
//! weight in a layer) is flattened to 1-D and sliced into `l = ⌈mn/N_out⌉`
//! blocks of `N_out` bits. The pruning mask is sliced identically; tail
//! padding is masked out (pruned ⇒ don't-care), which matches the paper's
//! handling of the final partial block.

use crate::gf2::{BitVecF2, Block};

/// A bit-plane sliced into `N_out`-bit blocks with a parallel mask.
#[derive(Debug, Clone)]
pub struct SlicedPlane {
    /// Data blocks (`l` entries), LSB-first bit packing.
    pub data: Vec<Block>,
    /// Mask blocks: bit set ⟺ position is *unpruned* (must match).
    pub mask: Vec<Block>,
    /// Original plane length in bits (before padding).
    pub n_bits: usize,
    /// Block width `N_out`.
    pub n_out: usize,
}

impl SlicedPlane {
    /// Slice `data` and `mask` (same length) into `n_out`-bit blocks.
    pub fn new(data: &BitVecF2, mask: &BitVecF2, n_out: usize) -> Self {
        assert_eq!(data.len(), mask.len(), "data/mask length mismatch");
        assert!(n_out >= 1 && n_out <= 128);
        let n_bits = data.len();
        let l = n_bits.div_ceil(n_out);
        let mut dblocks = Vec::with_capacity(l);
        let mut mblocks = Vec::with_capacity(l);
        for t in 0..l {
            let start = t * n_out;
            let width = n_out.min(n_bits - start);
            dblocks.push(data.block(start, width));
            // Tail bits beyond n_bits stay 0 in the mask: padding is free.
            mblocks.push(mask.block(start, width));
        }
        SlicedPlane { data: dblocks, mask: mblocks, n_bits, n_out }
    }

    /// Number of blocks `l`.
    #[inline]
    pub fn num_blocks(&self) -> usize {
        self.data.len()
    }

    /// Total unpruned bits (the denominator of encoding efficiency).
    pub fn unpruned_bits(&self) -> usize {
        self.mask.iter().map(|m| m.count_ones() as usize).sum()
    }

    /// Per-block unpruned counts `n_u` (for coefficient-of-variation
    /// statistics, §3.2).
    pub fn n_u(&self) -> Vec<u32> {
        self.mask.iter().map(|m| m.count_ones()).collect()
    }

    /// Reconstruct the flat (unsliced) data bits, for round-trip checks.
    pub fn to_bits(&self) -> BitVecF2 {
        let mut v = BitVecF2::zeros(self.n_bits);
        for (t, &b) in self.data.iter().enumerate() {
            let start = t * self.n_out;
            let width = self.n_out.min(self.n_bits - start);
            v.set_block(start, width, b);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn slicing_roundtrip() {
        let mut rng = Rng::new(1);
        let data = BitVecF2::random(1003, 0.5, &mut rng);
        let mask = BitVecF2::random(1003, 0.3, &mut rng);
        let p = SlicedPlane::new(&data, &mask, 80);
        assert_eq!(p.num_blocks(), 13);
        assert_eq!(p.to_bits(), data);
    }

    #[test]
    fn unpruned_counts_match_mask() {
        let mut rng = Rng::new(2);
        let data = BitVecF2::random(500, 0.5, &mut rng);
        let mask = BitVecF2::random(500, 0.25, &mut rng);
        let p = SlicedPlane::new(&data, &mask, 32);
        assert_eq!(p.unpruned_bits(), mask.count_ones());
        assert_eq!(
            p.n_u().iter().map(|&x| x as usize).sum::<usize>(),
            mask.count_ones()
        );
    }

    #[test]
    fn tail_padding_is_masked_out() {
        let data = BitVecF2::from_bools(&[true; 10]);
        let mask = BitVecF2::from_bools(&[true; 10]);
        let p = SlicedPlane::new(&data, &mask, 8);
        assert_eq!(p.num_blocks(), 2);
        // Second block: only 2 real bits → mask has exactly 2 set bits.
        assert_eq!(p.mask[1].count_ones(), 2);
        assert_eq!(p.data[1], 0b11);
    }
}

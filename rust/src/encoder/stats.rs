//! Encoding statistics: efficiency `E` (Eq. 1) and bit accounting.

/// Match bookkeeping for one encoded plane (or an aggregate of planes).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EncodeStats {
    /// Total bits in the plane (`l · N_out` minus nothing; includes
    /// pruned positions).
    pub total_bits: usize,
    /// Unpruned bits (mask popcount) — denominator of `E`.
    pub unpruned_bits: usize,
    /// Unpruned bits the decoder reproduces exactly — numerator of `E`.
    pub matched_bits: usize,
    /// Unpruned bits that mismatch (`unpruned − matched`).
    pub error_bits: usize,
    /// Encoded payload bits (`(l + N_s) · N_in`).
    pub encoded_bits: usize,
}

impl EncodeStats {
    /// Encoding efficiency `E` in percent (Eq. 1):
    /// `matched / unpruned × 100`. Defined as 100% for an empty mask.
    pub fn efficiency(&self) -> f64 {
        if self.unpruned_bits == 0 {
            100.0
        } else {
            self.matched_bits as f64 / self.unpruned_bits as f64 * 100.0
        }
    }

    /// Fold another plane's stats into an aggregate (e.g. across the 32
    /// bit-planes of an FP32 tensor, or across layers).
    pub fn merge(&mut self, other: &EncodeStats) {
        self.total_bits += other.total_bits;
        self.unpruned_bits += other.unpruned_bits;
        self.matched_bits += other.matched_bits;
        self.error_bits += other.error_bits;
        self.encoded_bits += other.encoded_bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_basic() {
        let s = EncodeStats {
            total_bits: 100,
            unpruned_bits: 40,
            matched_bits: 38,
            error_bits: 2,
            encoded_bits: 16,
        };
        assert!((s.efficiency() - 95.0).abs() < 1e-9);
    }

    #[test]
    fn empty_mask_is_perfect() {
        let s = EncodeStats::default();
        assert_eq!(s.efficiency(), 100.0);
    }

    #[test]
    fn merge_adds_fields() {
        let mut a = EncodeStats {
            total_bits: 10,
            unpruned_bits: 4,
            matched_bits: 4,
            error_bits: 0,
            encoded_bits: 2,
        };
        let b = EncodeStats {
            total_bits: 10,
            unpruned_bits: 6,
            matched_bits: 3,
            error_bits: 3,
            encoded_bits: 2,
        };
        a.merge(&b);
        assert_eq!(a.unpruned_bits, 10);
        assert_eq!(a.matched_bits, 7);
        assert!((a.efficiency() - 70.0).abs() < 1e-9);
    }
}

//! Weight encoding: find the input (sequence) whose decoded output best
//! matches the unpruned bits of each block.
//!
//! * [`ExhaustiveEncoder`] — the combinational case (`N_s = 0`): blocks
//!   are independent, so each block is an exhaustive search over the
//!   `2^{N_in}` decoder inputs (Kwon et al. 2020 baseline).
//! * [`ViterbiEncoder`] — the paper's contribution (§4, Algorithm 3):
//!   with shift registers the decoded output depends on `N_s + 1`
//!   consecutive inputs, so encoding is a maximum-likelihood sequence
//!   search on a hidden-Markov trellis with `2^{N_in·N_s}` states and
//!   `2^{N_in}` transitions, solved by dynamic programming in
//!   `O(l · 2^{N_in(N_s+1)})` time — minimizing total unmatched bits.
//!
//! Pruned positions are don't-cares: the error metric is the Hamming
//! distance restricted to mask bits (`gf2::masked_hamming`).

mod exhaustive;
mod plane;
mod stats;
mod viterbi;

pub use exhaustive::ExhaustiveEncoder;
pub use plane::SlicedPlane;
pub use stats::EncodeStats;
pub use viterbi::ViterbiEncoder;

use crate::decoder::SequentialDecoder;

/// Output of encoding one bit-plane.
#[derive(Debug, Clone)]
pub struct EncodeResult {
    /// Encoded stream, `l + N_s` chunks of `N_in` bits each (the first
    /// `N_s` chunks are the zero register pre-load, Algorithm 3).
    pub encoded: Vec<u32>,
    /// Match statistics (encoding efficiency `E`, Eq. 1).
    pub stats: EncodeStats,
    /// Flat bit positions (within the plane) where the decoded output
    /// disagrees with an *unpruned* original bit; exactly the bits the
    /// correction stream must flip for lossless reconstruction.
    pub mismatches: Vec<usize>,
}

impl EncodeResult {
    /// Encoding efficiency `E` in percent (Eq. 1).
    pub fn efficiency(&self) -> f64 {
        self.stats.efficiency()
    }
}

/// Shared trait so experiments can swap encoders.
pub trait Encoder {
    /// Encode a sliced plane, minimizing unmatched unpruned bits.
    fn encode(&self, plane: &SlicedPlane) -> EncodeResult;
    /// The decoder this encoder targets.
    fn decoder(&self) -> &SequentialDecoder;
}

/// Decode `encoded` with `dec` and diff against the plane: returns
/// (matched_unpruned_bits, mismatch_positions). Used by both encoders to
/// produce ground-truth statistics (and by tests to cross-check DP
/// bookkeeping).
pub(crate) fn diff_decoded(
    dec: &SequentialDecoder,
    plane: &SlicedPlane,
    encoded: &[u32],
) -> (usize, Vec<usize>) {
    let n_out = dec.spec().n_out;
    let blocks = dec.decode_stream(encoded);
    assert_eq!(blocks.len(), plane.num_blocks());
    let mut matched = 0usize;
    let mut mismatches = Vec::new();
    for (t, out) in blocks.iter().enumerate() {
        let diff = (out ^ plane.data[t]) & plane.mask[t];
        matched += (plane.mask[t].count_ones() - diff.count_ones()) as usize;
        let mut d = diff;
        while d != 0 {
            let b = d.trailing_zeros() as usize;
            mismatches.push(t * n_out + b);
            d &= d - 1;
        }
    }
    (matched, mismatches)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoder::DecoderSpec;
    use crate::gf2::BitVecF2;
    use crate::rng::Rng;

    /// Encoding must be decodable back to within the reported error count,
    /// for both encoders across several shapes.
    #[test]
    fn encode_then_decode_matches_reported_errors() {
        let mut rng = Rng::new(10);
        for &(n_in, n_out, n_s) in
            &[(4usize, 10usize, 0usize), (4, 10, 1), (4, 10, 2), (6, 18, 1)]
        {
            let spec = DecoderSpec::new(n_in, n_out, n_s);
            let dec = SequentialDecoder::random(spec, 123);
            let n_bits = 400;
            let data = BitVecF2::random(n_bits, 0.5, &mut rng);
            let mask = BitVecF2::random(n_bits, 0.4, &mut rng);
            let plane = SlicedPlane::new(&data, &mask, n_out);
            let enc = ViterbiEncoder::new(dec.clone());
            let res = enc.encode(&plane);
            let (matched, mism) = diff_decoded(&dec, &plane, &res.encoded);
            assert_eq!(matched, res.stats.matched_bits);
            assert_eq!(mism, res.mismatches);
            assert_eq!(
                res.stats.error_bits,
                res.mismatches.len(),
                "spec {spec:?}"
            );
        }
    }

    /// For N_s = 0 the Viterbi DP must agree exactly with exhaustive
    /// per-block search (same minimal error count).
    #[test]
    fn viterbi_ns0_equals_exhaustive() {
        let mut rng = Rng::new(77);
        let spec = DecoderSpec::new(6, 16, 0);
        let dec = SequentialDecoder::random(spec, 5);
        let data = BitVecF2::random(800, 0.5, &mut rng);
        let mask = BitVecF2::random(800, 0.3, &mut rng);
        let plane = SlicedPlane::new(&data, &mask, 16);
        let ex = ExhaustiveEncoder::new(dec.clone()).encode(&plane);
        let vit = ViterbiEncoder::new(dec).encode(&plane);
        assert_eq!(ex.stats.error_bits, vit.stats.error_bits);
        assert_eq!(ex.stats.matched_bits, vit.stats.matched_bits);
    }

    /// Sequential encoding (N_s > 0) must never do worse than N_s = 0 on
    /// average over random planes — the paper's central claim.
    #[test]
    fn sequential_beats_combinational_on_average() {
        // N_in = 6 keeps the debug-mode DP fast (4096 states).
        let mut rng = Rng::new(3);
        let n_out = 20;
        let mut err0 = 0usize;
        let mut err2 = 0usize;
        for trial in 0..5 {
            let data = BitVecF2::random(1_000, 0.5, &mut rng);
            let mask = BitVecF2::random(1_000, 0.4, &mut rng);
            let plane = SlicedPlane::new(&data, &mask, n_out);
            let d0 = SequentialDecoder::random(
                DecoderSpec::new(6, n_out, 0),
                trial,
            );
            let d2 = SequentialDecoder::random(
                DecoderSpec::new(6, n_out, 2),
                trial,
            );
            err0 += ViterbiEncoder::new(d0).encode(&plane).stats.error_bits;
            err2 += ViterbiEncoder::new(d2).encode(&plane).stats.error_bits;
        }
        assert!(
            err2 < err0,
            "sequential N_s=2 ({err2}) should beat N_s=0 ({err0})"
        );
    }

    /// A fully pruned plane encodes with zero errors (everything is a
    /// don't-care).
    #[test]
    fn all_pruned_plane_is_free() {
        let spec = DecoderSpec::new(4, 12, 1);
        let dec = SequentialDecoder::random(spec, 8);
        let data = BitVecF2::random(240, 0.5, &mut Rng::new(1));
        let mask = BitVecF2::zeros(240);
        let plane = SlicedPlane::new(&data, &mask, 12);
        let res = ViterbiEncoder::new(dec).encode(&plane);
        assert_eq!(res.stats.error_bits, 0);
        assert_eq!(res.stats.unpruned_bits, 0);
        assert_eq!(res.efficiency(), 100.0);
    }

    /// Sparse planes (few unpruned bits per block) should encode near
    /// perfectly when the rate rule holds.
    #[test]
    fn high_sparsity_encodes_nearly_perfectly() {
        let mut rng = Rng::new(4);
        let spec = DecoderSpec::for_sparsity(8, 0.9, 1); // N_out = 80
        let dec = SequentialDecoder::random(spec, 21);
        let n_bits = 8_000;
        let data = BitVecF2::random(n_bits, 0.5, &mut rng);
        let mask = BitVecF2::random(n_bits, 0.1, &mut rng); // S = 0.9
        let plane = SlicedPlane::new(&data, &mask, 80);
        let res = ViterbiEncoder::new(dec).encode(&plane);
        assert!(
            res.efficiency() > 95.0,
            "E = {:.2}% too low",
            res.efficiency()
        );
    }
}

//! Viterbi (maximum-likelihood sequence) encoder — Algorithm 3.
//!
//! Sequential decoding makes block `t` depend on inputs
//! `(w_t^e, …, w_{t-N_s}^e)`; naive encoding would cost
//! `O(2^{N_in·l})`. Modelling the register contents as a hidden-Markov
//! state (`2^{N_in·N_s}` states, `2^{N_in}` transitions) reduces it to
//! `O(l · 2^{N_in(N_s+1)})` time / `O(2^{N_in·N_s})` DP space via dynamic
//! programming, minimizing the total number of unmatched unpruned bits.
//!
//! State packing: the most recent chunk lives in the low `N_in` bits —
//! `s_t = i_t | i_{t-1} << N_in | …`. Registers pre-load to zero, so the
//! DP starts with only state 0 reachable (the paper fixes
//! `w_1^e = w_2^e = BIN(0)`).
//!
//! Hot-path layout (per time step, `N_s = 2` specialization):
//!
//! * fold `data_t`/`mask_t` into the slot-0 table once:
//!   `t0md[c] = (T0[c] ⊕ data_t) & mask_t`, `t1m/t2m` similarly;
//! * the candidate error is then a single XOR + popcount:
//!   `err = popcount(t0md[c] ⊕ t1m[lo] ⊕ t2m[hi])`;
//! * loop order `(lo, c, hi)` keeps `dp_old[lo | hi≪N_in]` and `t2m[hi]`
//!   streaming linearly in the innermost loop.
//!
//! An optional **beam** (`with_beam`) prunes source states whose cost
//! exceeds `current_min + beam`; with a random code the survivor set
//! collapses quickly, giving order-of-magnitude speedups at (measured —
//! see EXPERIMENTS.md §Perf) negligible loss in `E`. Exact DP is the
//! default everywhere results are reported unless stated otherwise.

use super::{diff_decoded, EncodeResult, Encoder, SlicedPlane};
use crate::decoder::SequentialDecoder;
use crate::encoder::EncodeStats;
use crate::gf2::Block;

const INF: u32 = u32::MAX / 2;

/// Gather the bits of `v` selected by `mask` into the low bits of a
/// `u64` (requires `mask.count_ones() ≤ 64`). The DP's error metric
/// only involves the `n_u` unpruned positions, so compacting lets the
/// inner loop work on one `u64` instead of a full 128-bit block —
/// linear over GF(2), so `compact(a ^ b) = compact(a) ^ compact(b)`.
#[inline]
fn compact_bits(v: Block, mask: Block) -> u64 {
    #[cfg(all(target_arch = "x86_64", target_feature = "bmi2"))]
    {
        // Two PEXTs (low/high lane) + shift-merge.
        // SAFETY: this arm only compiles on x86_64 with the `bmi2`
        // target feature enabled (`cfg` above), so the BMI2
        // `_pext_u64` instruction is statically guaranteed present.
        let lo = unsafe {
            std::arch::x86_64::_pext_u64(v as u64, mask as u64)
        };
        // SAFETY: same static `x86_64` + `bmi2` guarantee as above.
        let hi = unsafe {
            std::arch::x86_64::_pext_u64((v >> 64) as u64, (mask >> 64) as u64)
        };
        lo | (hi << (mask as u64).count_ones())
    }
    #[cfg(not(all(target_arch = "x86_64", target_feature = "bmi2")))]
    {
        let mut out = 0u64;
        let mut m = mask;
        let mut k = 0u32;
        while m != 0 {
            let b = m.trailing_zeros();
            out |= (((v >> b) & 1) as u64) << k;
            k += 1;
            m &= m - 1;
        }
        out
    }
}

/// Sequential DP encoder for any `N_s ≤ 4` (specialized for 0, 1, 2).
#[derive(Debug, Clone)]
pub struct ViterbiEncoder {
    decoder: SequentialDecoder,
    /// Source states with `dp > min + beam` are skipped when `Some`.
    beam: Option<u32>,
}

impl ViterbiEncoder {
    /// Exact DP encoder.
    pub fn new(decoder: SequentialDecoder) -> Self {
        ViterbiEncoder { decoder, beam: None }
    }

    /// Beam-pruned DP: keep states within `beam` errors of the running
    /// minimum. `beam = 0` keeps only optimal-so-far states.
    pub fn with_beam(decoder: SequentialDecoder, beam: u32) -> Self {
        ViterbiEncoder { decoder, beam: Some(beam) }
    }

    fn encode_ns0(&self, plane: &SlicedPlane) -> Vec<u32> {
        let table = self.decoder.tables().slot_table(0);
        plane
            .data
            .iter()
            .zip(&plane.mask)
            .map(|(&d, &m)| {
                let mut best = (0u32, u32::MAX);
                for (v, &out) in table.iter().enumerate() {
                    let err = ((out ^ d) & m).count_ones();
                    if err < best.1 {
                        best = (v as u32, err);
                        if err == 0 {
                            break;
                        }
                    }
                }
                best.0
            })
            .collect()
    }

    fn encode_ns1(&self, plane: &SlicedPlane) -> Vec<u32> {
        let spec = self.decoder.spec();
        let n_in = spec.n_in;
        let chunks = 1usize << n_in;
        let l = plane.num_blocks();
        let t0 = self.decoder.tables().slot_table(0);
        let t1 = self.decoder.tables().slot_table(1);

        let mut dp = vec![INF; chunks];
        dp[0] = 0;
        let mut dp_new = vec![INF; chunks];
        let mut path = vec![0u16; l * chunks];
        let mut t0md = vec![0 as Block; chunks];
        let mut t1m = vec![0 as Block; chunks];

        for t in 0..l {
            let (d, m) = (plane.data[t], plane.mask[t]);
            for c in 0..chunks {
                t0md[c] = (t0[c] ^ d) & m;
                t1m[c] = t1[c] & m;
            }
            let cutoff = self.cutoff(&dp);
            dp_new.fill(INF);
            let prow = &mut path[t * chunks..(t + 1) * chunks];
            for lo in 0..chunks {
                let base = dp[lo];
                if base > cutoff {
                    continue;
                }
                let x1 = t1m[lo];
                for c in 0..chunks {
                    let cand = base + (t0md[c] ^ x1).count_ones();
                    if cand < dp_new[c] {
                        dp_new[c] = cand;
                        prow[c] = lo as u16;
                    }
                }
            }
            std::mem::swap(&mut dp, &mut dp_new);
        }
        self.backtrack(plane, &dp, &path, chunks)
    }

    /// `N_s = 2` fast path.
    ///
    /// The naive relaxation scans all `2^{N_in}` source `hi` chunks per
    /// `(c, lo)` — `2^{3·N_in}` candidate evaluations per block. Three
    /// exact optimizations cut this by ~2 orders of magnitude (measured
    /// in EXPERIMENTS.md §Perf):
    ///
    /// 1. **Tier sort + early exit.** Per `lo`, sources are
    ///    counting-sorted by `dp_old`. Since `cand = dp_old + err ≥
    ///    dp_old`, the scan stops as soon as the next source's `dp_old`
    ///    is ≥ the best candidate found — with a random code at high
    ///    sparsity an exact match (`err = 0`) in the lowest tier ends
    ///    most scans after a handful of probes.
    /// 2. **Contiguous per-`lo` working set.** `dp_old` values and the
    ///    masked `T2` entries are re-laid-out in sorted order so the
    ///    inner loop streams flat arrays instead of gathering at stride
    ///    `2^{N_in}` (which blows L1).
    /// 3. **Bit compaction.** Only the `n_u` masked bits matter; they
    ///    are PEXT-gathered into one `u64` (`n_u ≤ 64` in practice), so
    ///    the error metric is a single XOR + POPCNT.
    fn encode_ns2(&self, plane: &SlicedPlane) -> Vec<u32> {
        let spec = self.decoder.spec();
        let n_in = spec.n_in;
        let chunks = 1usize << n_in;
        let n_states = chunks * chunks;
        let l = plane.num_blocks();
        let t0 = self.decoder.tables().slot_table(0);
        let t1 = self.decoder.tables().slot_table(1);
        let t2 = self.decoder.tables().slot_table(2);

        let mut dp = vec![INF; n_states];
        dp[0] = 0;
        let mut dp_new = vec![INF; n_states];
        let mut path = vec![0u16; l * n_states];
        let mut t0md = vec![0u64; chunks];
        let mut t1m = vec![0u64; chunks];
        let mut t2m = vec![0u64; chunks];
        let mut t0md_w = vec![0 as Block; chunks];
        let mut t1m_w = vec![0 as Block; chunks];
        let mut t2m_w = vec![0 as Block; chunks];
        let mut scratch = Ns2Scratch::new(chunks);

        for t in 0..l {
            let (d, m) = (plane.data[t], plane.mask[t]);
            let cutoff = self.cutoff(&dp);
            dp_new.fill(INF);
            let prow = &mut path[t * n_states..(t + 1) * n_states];
            if m.count_ones() <= 64 {
                for c in 0..chunks {
                    t0md[c] = compact_bits((t0[c] ^ d) & m, m);
                    t1m[c] = compact_bits(t1[c] & m, m);
                    t2m[c] = compact_bits(t2[c] & m, m);
                }
                relax_ns2(
                    &dp, &mut dp_new, prow, &t0md, &t1m, &t2m, n_in,
                    cutoff, &mut scratch,
                );
            } else {
                // Rare wide-mask fallback: full-width blocks.
                for c in 0..chunks {
                    t0md_w[c] = (t0[c] ^ d) & m;
                    t1m_w[c] = t1[c] & m;
                    t2m_w[c] = t2[c] & m;
                }
                relax_ns2(
                    &dp, &mut dp_new, prow, &t0md_w, &t1m_w, &t2m_w,
                    n_in, cutoff, &mut scratch,
                );
            }
            std::mem::swap(&mut dp, &mut dp_new);
        }
        self.backtrack(plane, &dp, &path, n_states)
    }

    /// Generic fallback for `N_s ≥ 3` (small `N_in` only).
    fn encode_generic(&self, plane: &SlicedPlane) -> Vec<u32> {
        let spec = self.decoder.spec();
        let n_in = spec.n_in;
        let ns = spec.n_s;
        let chunks = 1usize << n_in;
        let n_states = spec.num_states();
        let chunk_mask = chunks - 1;
        let l = plane.num_blocks();
        let tabs = self.decoder.tables();

        // hist[s] = Σ_{k=1..ns} T_k[chunk_{k-1}(s)] (mask applied later).
        let mut hist = vec![0 as Block; n_states];
        for (s, h) in hist.iter_mut().enumerate() {
            for k in 1..=ns {
                *h ^= tabs.slot(k, (s >> ((k - 1) * n_in)) & chunk_mask);
            }
        }
        let t0 = tabs.slot_table(0);

        let mut dp = vec![INF; n_states];
        dp[0] = 0;
        let mut dp_new = vec![INF; n_states];
        let mut path = vec![0u16; l * n_states];
        let keep = n_states >> n_in; // states sans oldest chunk

        for t in 0..l {
            let (d, m) = (plane.data[t], plane.mask[t]);
            let cutoff = self.cutoff(&dp);
            dp_new.fill(INF);
            let prow = &mut path[t * n_states..(t + 1) * n_states];
            for s_old in 0..n_states {
                let base = dp[s_old];
                if base > cutoff {
                    continue;
                }
                let oldest = (s_old / keep.max(1)) & chunk_mask;
                let carried = (s_old % keep.max(1)) << n_in;
                let h = (hist[s_old] ^ d) & m;
                for c in 0..chunks {
                    let cand = base + ((t0[c] & m) ^ h).count_ones();
                    let s_new = c | carried;
                    if cand < dp_new[s_new] {
                        dp_new[s_new] = cand;
                        prow[s_new] = oldest as u16;
                    }
                }
            }
            std::mem::swap(&mut dp, &mut dp_new);
        }
        self.backtrack(plane, &dp, &path, n_states)
    }

    /// Beam cutoff for the current DP front.
    fn cutoff(&self, dp: &[u32]) -> u32 {
        match self.beam {
            None => INF,
            Some(b) => {
                let min = dp.iter().copied().min().unwrap_or(0);
                min.saturating_add(b)
            }
        }
    }

    /// Walk the path array back from the best final state; returns the
    /// full encoded stream including the `N_s` zero pre-load chunks.
    fn backtrack(
        &self,
        plane: &SlicedPlane,
        dp: &[u32],
        path: &[u16],
        n_states: usize,
    ) -> Vec<u32> {
        let spec = self.decoder.spec();
        let n_in = spec.n_in;
        let ns = spec.n_s;
        let chunk_mask = (1usize << n_in) - 1;
        let l = plane.num_blocks();

        let mut s = dp
            .iter()
            .enumerate()
            .min_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap_or(0);

        let mut inputs = vec![0u32; l];
        for t in (0..l).rev() {
            inputs[t] = (s & chunk_mask) as u32;
            let oldest = path[t * n_states + s] as usize;
            s = (s >> n_in) | (oldest << (n_in * ns.saturating_sub(1)));
        }
        let mut encoded = vec![0u32; ns];
        encoded.extend(inputs);
        encoded
    }
}

/// Word abstraction so the `N_s = 2` relaxation runs on compacted `u64`
/// patterns (fast path) or full 128-bit blocks (wide-mask fallback).
trait Word: Copy {
    fn ham(self, other: Self) -> u32;
    fn bxor(self, other: Self) -> Self;
}

impl Word for u64 {
    #[inline(always)]
    fn ham(self, other: Self) -> u32 {
        (self ^ other).count_ones()
    }
    #[inline(always)]
    fn bxor(self, other: Self) -> Self {
        self ^ other
    }
}

impl Word for Block {
    #[inline(always)]
    fn ham(self, other: Self) -> u32 {
        (self ^ other).count_ones()
    }
    #[inline(always)]
    fn bxor(self, other: Self) -> Self {
        self ^ other
    }
}

/// Reusable buffers for [`relax_ns2`].
struct Ns2Scratch {
    src_dp: Vec<u32>,
    src_hi: Vec<u16>,
}

impl Ns2Scratch {
    fn new(chunks: usize) -> Self {
        Ns2Scratch { src_dp: vec![0; chunks], src_hi: vec![0; chunks] }
    }
}

/// One DP step of the `N_s = 2` trellis (see `encode_ns2` for the
/// optimization notes). Exact: early exits never skip an improving
/// candidate because sources are scanned in ascending `dp_old` order.
#[allow(clippy::too_many_arguments)]
fn relax_ns2<W: Word>(
    dp: &[u32],
    dp_new: &mut [u32],
    prow: &mut [u16],
    t0md: &[W],
    t1m: &[W],
    t2m: &[W],
    n_in: usize,
    cutoff: u32,
    scratch: &mut Ns2Scratch,
) {
    let chunks = 1usize << n_in;
    // Unreached states (dp = INF) are never sources.
    let lim = cutoff.min(INF - 1);
    let mut src_t2: Vec<W> = Vec::with_capacity(chunks);
    for lo in 0..chunks {
        // Collect + counting-sort sources by dp_old (ascending).
        let mut n_src = 0usize;
        let mut min_dp = u32::MAX;
        let mut max_dp = 0u32;
        for hi in 0..chunks {
            let v = dp[lo | (hi << n_in)];
            if v <= lim {
                min_dp = min_dp.min(v);
                max_dp = max_dp.max(v);
                n_src += 1;
            }
        }
        if n_src == 0 {
            continue;
        }
        src_t2.clear();
        src_t2.resize(n_src, t2m[0]);
        let span = (max_dp - min_dp) as usize + 1;
        if span <= 256 {
            let mut offs = vec![0u32; span + 1];
            for hi in 0..chunks {
                let v = dp[lo | (hi << n_in)];
                if v <= lim {
                    offs[(v - min_dp) as usize + 1] += 1;
                }
            }
            for i in 0..span {
                offs[i + 1] += offs[i];
            }
            for hi in 0..chunks {
                let v = dp[lo | (hi << n_in)];
                if v <= lim {
                    let slot = &mut offs[(v - min_dp) as usize];
                    let i = *slot as usize;
                    *slot += 1;
                    scratch.src_dp[i] = v;
                    scratch.src_hi[i] = hi as u16;
                    src_t2[i] = t2m[hi];
                }
            }
        } else {
            // Rare wide spread: comparison sort.
            let mut idx: Vec<usize> = (0..chunks)
                .filter(|&hi| dp[lo | (hi << n_in)] <= lim)
                .collect();
            idx.sort_unstable_by_key(|&hi| dp[lo | (hi << n_in)]);
            for (i, &hi) in idx.iter().enumerate() {
                scratch.src_dp[i] = dp[lo | (hi << n_in)];
                scratch.src_hi[i] = hi as u16;
                src_t2[i] = t2m[hi];
            }
        }

        let row = lo << n_in; // dp_new index base: c | lo << n_in
        let x1 = t1m[lo];
        let src_dp = &scratch.src_dp[..n_src];
        let src_hi = &scratch.src_hi[..n_src];
        for c in 0..chunks {
            let x = t0md[c].bxor(x1);
            let mut best = INF;
            let mut arg = 0u16;
            for i in 0..n_src {
                let dv = src_dp[i];
                if dv >= best {
                    break; // sorted: no later source can improve
                }
                let cand = dv + x.ham(src_t2[i]);
                if cand < best {
                    best = cand;
                    arg = src_hi[i];
                }
            }
            let idx = c | row;
            dp_new[idx] = best;
            prow[idx] = arg;
        }
    }
}

impl Encoder for ViterbiEncoder {
    fn encode(&self, plane: &SlicedPlane) -> EncodeResult {
        let spec = self.decoder.spec();
        assert_eq!(plane.n_out, spec.n_out, "plane/decoder N_out mismatch");
        let encoded = match spec.n_s {
            0 => {
                let mut e = self.encode_ns0(plane);
                e.splice(0..0, std::iter::empty());
                e
            }
            1 => self.encode_ns1(plane),
            2 => self.encode_ns2(plane),
            _ => self.encode_generic(plane),
        };
        let (matched, mismatches) =
            diff_decoded(&self.decoder, plane, &encoded);
        let unpruned = plane.unpruned_bits();
        EncodeResult {
            stats: EncodeStats {
                total_bits: plane.num_blocks() * plane.n_out,
                unpruned_bits: unpruned,
                matched_bits: matched,
                error_bits: unpruned - matched,
                encoded_bits: spec.encoded_bits(plane.n_bits),
            },
            encoded,
            mismatches,
        }
    }

    fn decoder(&self) -> &SequentialDecoder {
        &self.decoder
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoder::DecoderSpec;
    use crate::gf2::BitVecF2;
    use crate::rng::Rng;

    /// Brute-force optimal sequence error for tiny instances.
    fn brute_force_min_err(
        dec: &SequentialDecoder,
        plane: &SlicedPlane,
    ) -> u32 {
        let spec = dec.spec();
        let l = plane.num_blocks();
        let chunks = 1u32 << spec.n_in;
        let total = (chunks as u64).pow(l as u32);
        assert!(total <= 1 << 24, "instance too large for brute force");
        let mut best = u32::MAX;
        for combo in 0..total {
            let mut inputs = vec![0u32; spec.n_s];
            let mut c = combo;
            for _ in 0..l {
                inputs.push((c % chunks as u64) as u32);
                c /= chunks as u64;
            }
            let blocks = dec.decode_stream(&inputs);
            let err: u32 = blocks
                .iter()
                .zip(plane.data.iter().zip(&plane.mask))
                .map(|(o, (&d, &m))| ((o ^ d) & m).count_ones())
                .sum();
            best = best.min(err);
        }
        best
    }

    #[test]
    fn dp_is_optimal_vs_brute_force_ns1() {
        let mut rng = Rng::new(42);
        let spec = DecoderSpec::new(3, 8, 1);
        let dec = SequentialDecoder::random(spec, 17);
        for trial in 0..5 {
            let data = BitVecF2::random(40, 0.5, &mut rng);
            let mask = BitVecF2::random(40, 0.5, &mut rng);
            let plane = SlicedPlane::new(&data, &mask, 8);
            let res = ViterbiEncoder::new(dec.clone()).encode(&plane);
            let opt = brute_force_min_err(&dec, &plane);
            assert_eq!(
                res.stats.error_bits as u32, opt,
                "trial {trial}: DP {} vs brute {opt}",
                res.stats.error_bits
            );
        }
    }

    #[test]
    fn dp_is_optimal_vs_brute_force_ns2() {
        let mut rng = Rng::new(43);
        let spec = DecoderSpec::new(2, 6, 2);
        let dec = SequentialDecoder::random(spec, 23);
        for trial in 0..5 {
            let data = BitVecF2::random(48, 0.5, &mut rng);
            let mask = BitVecF2::random(48, 0.6, &mut rng);
            let plane = SlicedPlane::new(&data, &mask, 6);
            let res = ViterbiEncoder::new(dec.clone()).encode(&plane);
            let opt = brute_force_min_err(&dec, &plane);
            assert_eq!(res.stats.error_bits as u32, opt, "trial {trial}");
        }
    }

    #[test]
    fn dp_is_optimal_vs_brute_force_ns3_generic_path() {
        let mut rng = Rng::new(44);
        let spec = DecoderSpec::new(2, 5, 3);
        let dec = SequentialDecoder::random(spec, 29);
        for trial in 0..3 {
            let data = BitVecF2::random(40, 0.5, &mut rng);
            let mask = BitVecF2::random(40, 0.5, &mut rng);
            let plane = SlicedPlane::new(&data, &mask, 5);
            let res = ViterbiEncoder::new(dec.clone()).encode(&plane);
            let opt = brute_force_min_err(&dec, &plane);
            assert_eq!(res.stats.error_bits as u32, opt, "trial {trial}");
        }
    }

    #[test]
    fn beam_never_beats_exact_and_wide_beam_matches() {
        let mut rng = Rng::new(45);
        let spec = DecoderSpec::new(4, 12, 2);
        let dec = SequentialDecoder::random(spec, 31);
        let data = BitVecF2::random(600, 0.5, &mut rng);
        let mask = BitVecF2::random(600, 0.4, &mut rng);
        let plane = SlicedPlane::new(&data, &mask, 12);
        let exact = ViterbiEncoder::new(dec.clone()).encode(&plane);
        let wide = ViterbiEncoder::with_beam(dec.clone(), 64).encode(&plane);
        let narrow = ViterbiEncoder::with_beam(dec, 1).encode(&plane);
        assert_eq!(exact.stats.error_bits, wide.stats.error_bits);
        assert!(narrow.stats.error_bits >= exact.stats.error_bits);
    }

    #[test]
    fn encoded_stream_has_zero_preload() {
        let spec = DecoderSpec::new(4, 12, 2);
        let dec = SequentialDecoder::random(spec, 3);
        let mut rng = Rng::new(46);
        let data = BitVecF2::random(120, 0.5, &mut rng);
        let mask = BitVecF2::random(120, 0.5, &mut rng);
        let plane = SlicedPlane::new(&data, &mask, 12);
        let res = ViterbiEncoder::new(dec).encode(&plane);
        assert_eq!(res.encoded.len(), 10 + 2);
        assert_eq!(res.encoded[0], 0);
        assert_eq!(res.encoded[1], 0);
    }
}

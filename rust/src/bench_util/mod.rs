//! Minimal timing harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use [`bench`] / [`bench_with_result`]: warm up,
//! run timed iterations until a budget is reached, report mean / p50 /
//! p95 / min. Deterministic workloads + wall-clock medians keep results
//! stable enough for before/after comparisons in EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

/// Summary of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// Throughput in items/sec given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

/// Run `f` repeatedly: `warmup` unmeasured iterations, then measured
/// iterations until `budget` elapses (min 5, max `max_iters`).
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    budget: Duration,
    max_iters: usize,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while (samples.len() < 5 || start.elapsed() < budget)
        && samples.len() < max_iters
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    let res = summarize(&mut samples);
    println!(
        "{name:<48} iters={:<4} mean={:>12?} p50={:>12?} p95={:>12?} min={:>12?}",
        res.iters, res.mean, res.p50, res.p95, res.min
    );
    res
}

/// Like [`bench`] but the closure returns a value that is black-boxed to
/// keep the optimizer honest.
pub fn bench_with_result<T, F: FnMut() -> T>(
    name: &str,
    warmup: usize,
    budget: Duration,
    max_iters: usize,
    mut f: F,
) -> BenchResult {
    bench(name, warmup, budget, max_iters, || {
        black_box(f());
    })
}

/// Opaque value sink (stable `std::hint::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

fn summarize(samples: &mut Vec<Duration>) -> BenchResult {
    samples.sort_unstable();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    BenchResult {
        iters: n,
        mean,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        min: samples[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_minimum_iterations() {
        let mut count = 0;
        let r = bench(
            "noop",
            1,
            Duration::from_millis(1),
            100,
            || {
                count += 1;
            },
        );
        assert!(r.iters >= 5);
        assert_eq!(count, r.iters + 1); // + warmup
    }

    #[test]
    fn respects_max_iters() {
        let r = bench("capped", 0, Duration::from_secs(10), 7, || {});
        assert_eq!(r.iters, 7);
    }

    #[test]
    fn throughput_positive() {
        let r = bench("t", 0, Duration::from_millis(1), 10, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.throughput(1000.0) > 0.0);
    }
}

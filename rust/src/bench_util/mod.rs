//! Minimal timing harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use [`bench`] / [`bench_with_result`]: warm up,
//! run timed iterations until a budget is reached, report mean / p50 /
//! p95 / min. Deterministic workloads + wall-clock medians keep results
//! stable enough for before/after comparisons in EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

/// Summary of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// Throughput in items/sec given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

/// Run `f` repeatedly: `warmup` unmeasured iterations, then measured
/// iterations until `budget` elapses (min 5, max `max_iters`).
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    budget: Duration,
    max_iters: usize,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while (samples.len() < 5 || start.elapsed() < budget)
        && samples.len() < max_iters
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    let res = summarize(&mut samples);
    println!(
        "{name:<48} iters={:<4} mean={:>12?} p50={:>12?} p95={:>12?} min={:>12?}",
        res.iters, res.mean, res.p50, res.p95, res.min
    );
    res
}

/// Like [`bench`] but the closure returns a value that is black-boxed to
/// keep the optimizer honest.
pub fn bench_with_result<T, F: FnMut() -> T>(
    name: &str,
    warmup: usize,
    budget: Duration,
    max_iters: usize,
    mut f: F,
) -> BenchResult {
    bench(name, warmup, budget, max_iters, || {
        black_box(f());
    })
}

/// Opaque value sink (stable `std::hint::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One timed serving pass: run a single `forward_batch` through any
/// [`crate::coordinator::Backend`] and return the outputs with the
/// wall time it took. The cold/warm pass primitive shared by
/// `benches/store.rs` and `examples/serve_compressed.rs` (both used
/// to hand-roll this loop), so every timed pass in the repo measures
/// the same thing the same way.
pub fn timed_pass<B>(
    backend: &mut B,
    batch: &[Vec<f32>],
) -> anyhow::Result<(Vec<Vec<f32>>, Duration)>
where
    B: crate::coordinator::Backend + ?Sized,
{
    let start = Instant::now();
    let ys = backend.forward_batch(batch)?;
    Ok((ys, start.elapsed()))
}

/// Machine-readable benchmark report: flat `case → {metric: number}`
/// JSON, hand-rolled (no serde offline). Start of the perf trajectory —
/// a driver can diff `BENCH_*.json` files across commits.
#[derive(Debug, Clone, Default)]
pub struct JsonReport {
    title: String,
    cases: Vec<(String, Vec<(String, f64)>)>,
}

impl JsonReport {
    /// New report with a title.
    pub fn new(title: &str) -> Self {
        JsonReport { title: title.to_string(), cases: Vec::new() }
    }

    /// Record a [`BenchResult`] under `case` (seconds-based metrics).
    pub fn add(&mut self, case: &str, r: &BenchResult) {
        self.metric(case, "iters", r.iters as f64);
        self.metric(case, "mean_s", r.mean.as_secs_f64());
        self.metric(case, "p50_s", r.p50.as_secs_f64());
        self.metric(case, "p95_s", r.p95.as_secs_f64());
        self.metric(case, "min_s", r.min.as_secs_f64());
    }

    /// Record one named metric under `case` (creates the case on first
    /// use; non-finite values are stored as 0 to keep the JSON valid).
    pub fn metric(&mut self, case: &str, key: &str, value: f64) {
        let value = if value.is_finite() { value } else { 0.0 };
        let entry = match self.cases.iter_mut().find(|(c, _)| c.as_str() == case) {
            Some(e) => e,
            None => {
                self.cases.push((case.to_string(), Vec::new()));
                self.cases.last_mut().unwrap()
            }
        };
        entry.1.push((key.to_string(), value));
    }

    /// Render the report as a JSON object string.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!(
            "  \"title\": \"{}\",\n  \"cases\": {{\n",
            escape_json(&self.title)
        ));
        for (ci, (case, metrics)) in self.cases.iter().enumerate() {
            out.push_str(&format!("    \"{}\": {{", escape_json(case)));
            for (mi, (key, value)) in metrics.iter().enumerate() {
                out.push_str(&format!("\"{}\": {}", escape_json(key), value));
                if mi + 1 < metrics.len() {
                    out.push_str(", ");
                }
            }
            out.push('}');
            if ci + 1 < self.cases.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("  }\n}\n");
        out
    }

    /// Write the JSON to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

fn summarize(samples: &mut Vec<Duration>) -> BenchResult {
    samples.sort_unstable();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    BenchResult {
        iters: n,
        mean,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        min: samples[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_minimum_iterations() {
        let mut count = 0;
        let r = bench(
            "noop",
            1,
            Duration::from_millis(1),
            100,
            || {
                count += 1;
            },
        );
        assert!(r.iters >= 5);
        assert_eq!(count, r.iters + 1); // + warmup
    }

    #[test]
    fn respects_max_iters() {
        let r = bench("capped", 0, Duration::from_secs(10), 7, || {});
        assert_eq!(r.iters, 7);
    }

    #[test]
    fn json_report_renders_valid_shape() {
        let mut rep = JsonReport::new("unit \"test\"");
        let r = bench("j", 0, Duration::from_millis(1), 5, || {});
        rep.add("case_a", &r);
        rep.metric("case_a", "throughput", 123.5);
        rep.metric("case_b", "bad", f64::NAN);
        let json = rep.to_json();
        assert!(json.contains("\"title\": \"unit \\\"test\\\"\""));
        assert!(json.contains("\"case_a\""));
        assert!(json.contains("\"throughput\": 123.5"));
        assert!(json.contains("\"bad\": 0"));
        // Balanced braces — cheap structural sanity.
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count()
        );
    }

    #[test]
    fn timed_pass_returns_outputs_and_elapsed() {
        use crate::coordinator::NativeBackend;
        use crate::sparse::DecodedLayer;
        let mut b = NativeBackend::from_decoded(DecodedLayer {
            rows: 1,
            cols: 2,
            weights: vec![1.0, 2.0],
        });
        let (ys, dt) =
            timed_pass(&mut b, &[vec![3.0, 4.0], vec![0.5, 0.0]])
                .unwrap();
        assert_eq!(ys, vec![vec![11.0], vec![0.5]]);
        assert!(dt <= Duration::from_secs(60), "sane wall time");
    }

    #[test]
    fn throughput_positive() {
        let r = bench("t", 0, Duration::from_millis(1), 10, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.throughput(1000.0) > 0.0);
    }
}

//! Poison-tolerant lock helpers for the serving path.
//!
//! A panic while holding a `std::sync` mutex poisons it, and every
//! later `.lock().unwrap()` on that mutex re-panics — so one panicking
//! decode job cascades into unrelated requests failing forever (the
//! exact failure `store/pool.rs` exhibited before this module). Every
//! shared mutex on the serving path guards *plain data* whose
//! invariants are re-established by the owning subsystem, not by the
//! panicking critical section: a cache map plus byte counters that are
//! checked by `debug_assertions` invariant sweeps, a connection slot
//! that is simply redialed, a metrics table where a torn EWMA update
//! is one bad sample. For those, the right response to poisoning is to
//! take the data and keep serving.
//!
//! These helpers make that policy explicit and greppable — the repo's
//! own `f2f lint` forbids bare `.lock().unwrap()` in serving modules
//! (rule `lock-poison`), and this is the sanctioned replacement.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// Poisoning is advisory: the data is still there, and on the serving
/// path every mutex-guarded structure is either self-healing
/// (reconnect, re-decode) or validated separately by debug invariant
/// checks, so we always prefer degraded service over a panic cascade.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Wait on `cv`, recovering the guard if the mutex was poisoned while
/// this thread slept. The caller's predicate loop re-checks the guarded
/// state either way, so a poisoned wake behaves like a spurious one.
pub fn wait_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    fn poison(m: &Arc<Mutex<u32>>) {
        let m = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
    }

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        poison(&m);
        assert!(m.lock().is_err(), "mutex should be poisoned");
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn wait_recovers_from_poison() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let waker = {
            let pair = pair.clone();
            std::thread::spawn(move || {
                let (m, cv) = &*pair;
                // Poison the mutex from a panicking holder, then flip
                // the flag through the recovered guard and wake the
                // waiter.
                let _ = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        let _g = m.lock().unwrap();
                        panic!("poison while the main thread waits");
                    }),
                );
                *lock_unpoisoned(m) = true;
                cv.notify_all();
            })
        };
        let (m, cv) = &*pair;
        let mut g = lock_unpoisoned(m);
        while !*g {
            g = wait_unpoisoned(cv, g);
        }
        drop(g);
        waker.join().unwrap();
    }
}

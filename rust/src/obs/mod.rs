//! Crate-wide observability: span tracing + latency histograms.
//!
//! The serving stack spans threads (batcher → worker → decode service)
//! and processes (router → `shard-worker` over unix sockets); coarse
//! EWMA averages say *that* it is slow, never *where*. This module is
//! the substrate that answers "where did this request spend its time":
//!
//! * **Span recorder** — a fixed-size ring buffer of
//!   [`SpanEvent`]s (`{trace_id, kind, label, t_start_ns, dur_ns}`).
//!   Recording is allocation-free: a relaxed atomic slot claim plus an
//!   uncontended per-slot `try_lock` (contended slots count as dropped
//!   rather than block the hot path). One global recorder per process;
//!   `shard-worker` processes expose theirs over the wire so a
//!   cross-process timeline can be stitched.
//! * **Trace context** — [`mint_trace`] allocates a process-unique
//!   trace id; [`with_trace`] pins it to the current thread for the
//!   duration of a guard. The inference server mints one per batch
//!   leader, the forward chain and stores read it implicitly, the IPC
//!   client sends it inside `Fetch`/`Prefetch` frames, and the worker
//!   re-pins it around request handling — so a decode running three
//!   hops away still lands under the originating request's trace.
//! * **Span taxonomy** ([`SpanKind`]) — `enqueue`/`queue` (batcher),
//!   `batch_form`/`batch` (formation and execution), `gemv` (per
//!   layer), `decode` (submit→install on the decode service),
//!   `readahead_plan`/`readahead_skip`, `cache_hit`/`cache_miss`/
//!   `evict` (model store), `ipc_fetch`/`ipc_prefetch` (wire round
//!   trips).
//! * **Histograms** — [`HdrLite`], 64 pow-2 buckets, mergeable,
//!   wire-flat; the percentile engine under
//!   [`crate::coordinator::MetricsSnapshot`] and
//!   [`crate::store::StoreMetrics`].
//! * **Exporters** — [`chrome_trace`] renders recorded events as
//!   Chrome trace-event JSON (`chrome://tracing` / Perfetto, one pid
//!   lane per process); `f2f serve --trace-out` / `--metrics-out`
//!   drive it from the CLI.
//!
//! Recording compiles out with `--no-default-features` (the `obs`
//! feature, on by default): every `span`/`event` call becomes a no-op
//! and the ring buffer is never allocated. With the feature on, a
//! runtime kill switch ([`set_enabled`]) lets one binary measure the
//! recorder's own overhead (see `obs_overhead_pct` in
//! `benches/store.rs`). Trace-id minting stays available either way —
//! it is one relaxed atomic increment and the wire format carries it
//! unconditionally.
//!
//! On top of the recorder sits the **live operations plane** (PR 8):
//!
//! * [`events`] — a structured, leveled, rate-limited JSONL event
//!   journal (the replacement for ad-hoc `eprintln!`), tailed over
//!   the stats socket and persisted with `serve --events-out`.
//! * [`stats`] — on-demand JSON snapshots of a *running* server
//!   (merged store metrics, cost EWMAs, request quantiles, queue
//!   depth) served on a dedicated unix socket; `f2f top` renders
//!   them as a refreshing table.
//! * [`flight`] — a crash flight recorder: workers checkpoint their
//!   span ring and journal tail to a binary sidecar so the
//!   supervisor can write a postmortem for a worker that died
//!   without answering `TraceDump`.
//! * [`watchdog`] — rolling-baseline regression detection over the
//!   live signals, emitting `anomaly` journal events.

mod export;
mod hist;

pub mod events;
pub mod flight;
pub mod stats;
pub mod watchdog;

pub use export::{chrome_trace, ProcessLane};
pub use hist::{HdrLite, HDR_BUCKETS, HDR_WIRE_FIELDS};

use crate::sync::lock_unpoisoned;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

/// The null trace id: events recorded outside any request context.
pub const TRACE_NONE: u64 = 0;

/// Ring-buffer capacity of the global recorder (events, not bytes).
pub const DEFAULT_EVENT_CAPACITY: usize = 65_536;

/// Label bytes carried inline per event (longer labels truncate at a
/// UTF-8 boundary — layer names are short; nothing allocates).
pub const MAX_LABEL_BYTES: usize = 32;

/// What a span measures. The discriminant is the wire encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// A request entered the batcher queue (instant).
    Enqueue = 0,
    /// Time a request waited in the queue (enqueue → dequeue).
    Queue = 1,
    /// Batch formation: first member's enqueue → batch closed.
    BatchForm = 2,
    /// One batch's forward execution.
    Batch = 3,
    /// One layer's GEMV phase over the whole batch.
    Gemv = 4,
    /// One layer decode, submit → install (queue wait included).
    Decode = 5,
    /// A readahead plan was issued for the labeled layer (instant).
    ReadaheadPlan = 6,
    /// A readahead was declined by budget admission (instant).
    ReadaheadSkip = 7,
    /// Store cache hit (instant).
    CacheHit = 8,
    /// Store cache miss (instant).
    CacheMiss = 9,
    /// A decoded layer was evicted (instant).
    Evict = 10,
    /// One IPC fetch round trip (request sent → layer received).
    IpcFetch = 11,
    /// One IPC prefetch round trip (request sent → ack received).
    IpcPrefetch = 12,
}

impl SpanKind {
    /// Every kind, in discriminant order.
    pub const ALL: [SpanKind; 13] = [
        SpanKind::Enqueue,
        SpanKind::Queue,
        SpanKind::BatchForm,
        SpanKind::Batch,
        SpanKind::Gemv,
        SpanKind::Decode,
        SpanKind::ReadaheadPlan,
        SpanKind::ReadaheadSkip,
        SpanKind::CacheHit,
        SpanKind::CacheMiss,
        SpanKind::Evict,
        SpanKind::IpcFetch,
        SpanKind::IpcPrefetch,
    ];

    /// Stable snake_case name (the Chrome-trace event name).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Enqueue => "enqueue",
            SpanKind::Queue => "queue",
            SpanKind::BatchForm => "batch_form",
            SpanKind::Batch => "batch",
            SpanKind::Gemv => "gemv",
            SpanKind::Decode => "decode",
            SpanKind::ReadaheadPlan => "readahead_plan",
            SpanKind::ReadaheadSkip => "readahead_skip",
            SpanKind::CacheHit => "cache_hit",
            SpanKind::CacheMiss => "cache_miss",
            SpanKind::Evict => "evict",
            SpanKind::IpcFetch => "ipc_fetch",
            SpanKind::IpcPrefetch => "ipc_prefetch",
        }
    }

    /// Wire discriminant.
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    /// Decode a wire discriminant (`None` for kinds from a newer
    /// peer — callers drop such events rather than error).
    pub fn from_u8(v: u8) -> Option<SpanKind> {
        SpanKind::ALL.get(v as usize).copied()
    }

    /// True for point events (rendered as instants, not slices).
    pub fn is_instant(self) -> bool {
        matches!(
            self,
            SpanKind::Enqueue
                | SpanKind::ReadaheadPlan
                | SpanKind::ReadaheadSkip
                | SpanKind::CacheHit
                | SpanKind::CacheMiss
                | SpanKind::Evict
        )
    }
}

/// One recorded span: fixed-size, `Copy`, no heap — the ring-buffer
/// slot type and the wire `TraceReply` element.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// The request trace this span belongs to ([`TRACE_NONE`] when
    /// recorded outside any request context).
    pub trace_id: u64,
    /// Start of the span, nanoseconds since the unix epoch (wall
    /// clock, so lanes from different processes align).
    pub t_start_ns: u64,
    /// Span length in nanoseconds (0 for instants).
    pub dur_ns: u64,
    /// What was measured.
    pub kind: SpanKind,
    label_len: u8,
    label: [u8; MAX_LABEL_BYTES],
}

impl SpanEvent {
    /// Build an event; `label` truncates to [`MAX_LABEL_BYTES`] at a
    /// UTF-8 boundary.
    pub fn new(
        trace_id: u64,
        kind: SpanKind,
        label: &str,
        t_start_ns: u64,
        dur_ns: u64,
    ) -> SpanEvent {
        let mut n = label.len().min(MAX_LABEL_BYTES);
        while n > 0 && !label.is_char_boundary(n) {
            n -= 1;
        }
        let mut buf = [0u8; MAX_LABEL_BYTES];
        buf[..n].copy_from_slice(&label.as_bytes()[..n]);
        SpanEvent {
            trace_id,
            t_start_ns,
            dur_ns,
            kind,
            label_len: n as u8,
            label: buf,
        }
    }

    /// The span's label (usually a layer name; may be empty).
    pub fn label(&self) -> &str {
        std::str::from_utf8(&self.label[..self.label_len as usize])
            .unwrap_or("")
    }
}

/// Fixed-size concurrent ring buffer of [`SpanEvent`]s. Recording
/// claims a slot with one relaxed `fetch_add` and writes it under an
/// uncontended per-slot `try_lock`; a contended slot (another thread
/// mid-write on the same wrapped index) counts as dropped instead of
/// blocking. Snapshots are the cold path: they lock slot by slot.
#[derive(Debug)]
pub struct SpanRecorder {
    slots: Vec<std::sync::Mutex<Option<SpanEvent>>>,
    head: AtomicU64,
    dropped: AtomicU64,
}

impl SpanRecorder {
    /// A recorder holding the newest `capacity` events (min 1).
    pub fn new(capacity: usize) -> SpanRecorder {
        let capacity = capacity.max(1);
        SpanRecorder {
            slots: (0..capacity)
                .map(|_| std::sync::Mutex::new(None))
                .collect(),
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Record one event (lock-cheap, allocation-free).
    pub fn record(&self, ev: SpanEvent) {
        let i = self.head.fetch_add(1, Ordering::Relaxed) as usize
            % self.slots.len();
        match self.slots[i].try_lock() {
            Ok(mut slot) => *slot = Some(ev),
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Copy out every retained event, ordered by start time. A slot
    /// poisoned by a panicking writer still yields its last complete
    /// value (`SpanEvent` is `Copy`: a slot is never half-written).
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let mut out: Vec<SpanEvent> = self
            .slots
            .iter()
            .filter_map(|s| *lock_unpoisoned(s))
            .collect();
        out.sort_by_key(|e| (e.t_start_ns, e.dur_ns));
        self.check_invariants(out.len());
        out
    }

    /// Discard every retained event.
    pub fn clear(&self) {
        for s in &self.slots {
            *lock_unpoisoned(s) = None;
        }
    }

    /// Debug-build audit of the ring's structural invariants, run on
    /// every snapshot. Compiled out of release builds.
    #[cfg(debug_assertions)]
    fn check_invariants(&self, retained: usize) {
        debug_assert!(
            retained <= self.slots.len(),
            "ring retained {retained} events over capacity {}",
            self.slots.len()
        );
        let claims = self.head.load(Ordering::Relaxed);
        let dropped = self.dropped.load(Ordering::Relaxed);
        debug_assert!(
            dropped <= claims,
            "ring dropped {dropped} events but only {claims} were claimed"
        );
        debug_assert!(
            retained as u64 <= claims,
            "ring retains {retained} events but only {claims} were claimed"
        );
    }

    #[cfg(not(debug_assertions))]
    fn check_invariants(&self, _retained: usize) {}

    /// Events lost to slot contention or ring wrap-around of an
    /// in-progress write (not wrap-around itself, which overwrites).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------
// Trace context: always compiled (one atomic + one thread-local cell);
// only *recording* is feature-gated.
// ---------------------------------------------------------------------

thread_local! {
    static CURRENT_TRACE: std::cell::Cell<u64> =
        const { std::cell::Cell::new(TRACE_NONE) };
}

/// Allocate a fresh trace id, unique within this process and salted
/// with the pid so ids from router and workers never collide.
pub fn mint_trace() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    ((std::process::id() as u64 & 0xFFFF) << 48) | (n & 0xFFFF_FFFF_FFFF)
}

/// The trace id pinned to this thread ([`TRACE_NONE`] outside any).
pub fn current_trace() -> u64 {
    CURRENT_TRACE.with(|c| c.get())
}

/// Restores the previous thread trace id on drop.
#[must_use = "the trace is unpinned when the guard drops"]
pub struct TraceGuard {
    prev: u64,
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        CURRENT_TRACE.with(|c| c.set(self.prev));
    }
}

/// Pin `trace_id` to the current thread until the guard drops.
pub fn with_trace(trace_id: u64) -> TraceGuard {
    let prev = CURRENT_TRACE.with(|c| c.replace(trace_id));
    TraceGuard { prev }
}

/// Pin the current trace if one exists, else mint and pin a fresh one
/// — how `forward_batch` entry points guarantee every pass has a
/// trace without double-minting under the inference server.
pub fn ensure_trace() -> TraceGuard {
    let cur = current_trace();
    if cur == TRACE_NONE {
        with_trace(mint_trace())
    } else {
        with_trace(cur)
    }
}

// ---------------------------------------------------------------------
// Global recorder + recording entry points (feature-gated bodies).
// ---------------------------------------------------------------------

#[cfg(feature = "obs")]
mod hot {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::OnceLock;

    pub(super) static ENABLED: AtomicBool = AtomicBool::new(true);

    pub(super) fn global() -> &'static SpanRecorder {
        static GLOBAL: OnceLock<SpanRecorder> = OnceLock::new();
        GLOBAL.get_or_init(|| SpanRecorder::new(DEFAULT_EVENT_CAPACITY))
    }
}

/// True when recording is compiled in *and* runtime-enabled.
pub fn enabled() -> bool {
    #[cfg(feature = "obs")]
    {
        hot::ENABLED.load(Ordering::Relaxed)
    }
    #[cfg(not(feature = "obs"))]
    {
        false
    }
}

/// Runtime kill switch (no-op when the `obs` feature is off). Lets one
/// binary measure the recorder's own overhead.
pub fn set_enabled(on: bool) {
    #[cfg(feature = "obs")]
    hot::ENABLED.store(on, Ordering::Relaxed);
    #[cfg(not(feature = "obs"))]
    let _ = on;
}

/// Nanoseconds since the unix epoch (wall clock — cross-process lanes
/// must share a clock, which `Instant` does not).
pub fn unix_now_ns() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| u64::try_from(d.as_nanos()).unwrap_or(u64::MAX))
        .unwrap_or(0)
}

/// Write `bytes` to `path` atomically: a sibling `.tmp` file is
/// written in full, then renamed over the target, so a concurrent
/// reader (the supervisor parsing a flight sidecar, CI tailing an
/// incremental export) never observes a torn file.
pub fn write_atomic(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)
}

#[cfg(feature = "obs")]
fn saturating_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// Record a completed span of `dur` ending now, under an explicit
/// trace id.
pub fn span_for(trace_id: u64, kind: SpanKind, label: &str, dur: Duration) {
    #[cfg(feature = "obs")]
    if enabled() {
        let dur_ns = saturating_ns(dur);
        let start = unix_now_ns().saturating_sub(dur_ns);
        hot::global()
            .record(SpanEvent::new(trace_id, kind, label, start, dur_ns));
    }
    #[cfg(not(feature = "obs"))]
    {
        let _ = (trace_id, kind, label, dur);
    }
}

/// Record a completed span of `dur` ending now, under the current
/// thread's trace.
pub fn span(kind: SpanKind, label: &str, dur: Duration) {
    span_for(current_trace(), kind, label, dur);
}

/// Record an instant event under an explicit trace id.
pub fn event_for(trace_id: u64, kind: SpanKind, label: &str) {
    span_for(trace_id, kind, label, Duration::ZERO);
}

/// Record an instant event under the current thread's trace.
pub fn event(kind: SpanKind, label: &str) {
    span_for(current_trace(), kind, label, Duration::ZERO);
}

/// Snapshot the global recorder (empty when `obs` is compiled out).
pub fn snapshot() -> Vec<SpanEvent> {
    #[cfg(feature = "obs")]
    {
        hot::global().snapshot()
    }
    #[cfg(not(feature = "obs"))]
    {
        Vec::new()
    }
}

/// Clear the global recorder (no-op when `obs` is compiled out).
pub fn clear() {
    #[cfg(feature = "obs")]
    hot::global().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_event_truncates_labels_at_char_boundaries() {
        let e = SpanEvent::new(1, SpanKind::Gemv, "mlp/fc0", 10, 5);
        assert_eq!(e.label(), "mlp/fc0");
        assert_eq!(e.trace_id, 1);
        assert_eq!(e.dur_ns, 5);
        let long = "x".repeat(MAX_LABEL_BYTES + 10);
        let e = SpanEvent::new(1, SpanKind::Gemv, &long, 0, 0);
        assert_eq!(e.label().len(), MAX_LABEL_BYTES);
        // A multi-byte char straddling the cut is dropped whole.
        let tricky = format!("{}é", "a".repeat(MAX_LABEL_BYTES - 1));
        let e = SpanEvent::new(1, SpanKind::Gemv, &tricky, 0, 0);
        assert_eq!(e.label(), "a".repeat(MAX_LABEL_BYTES - 1));
    }

    #[test]
    fn kinds_round_trip_their_wire_discriminant() {
        for k in SpanKind::ALL {
            assert_eq!(SpanKind::from_u8(k.as_u8()), Some(k));
            assert!(!k.name().is_empty());
        }
        assert_eq!(SpanKind::from_u8(200), None, "future kinds drop");
    }

    #[test]
    fn recorder_retains_newest_and_orders_snapshots() {
        let r = SpanRecorder::new(4);
        for i in 0..6u64 {
            r.record(SpanEvent::new(i, SpanKind::Gemv, "l", 100 - i, 0));
        }
        let snap = r.snapshot();
        assert_eq!(snap.len(), 4, "ring keeps the newest capacity");
        // Ordered by start time regardless of record order.
        for w in snap.windows(2) {
            assert!(w[0].t_start_ns <= w[1].t_start_ns);
        }
        r.clear();
        assert!(r.snapshot().is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn trace_context_nests_and_restores() {
        assert_eq!(current_trace(), TRACE_NONE);
        let a = mint_trace();
        let b = mint_trace();
        assert_ne!(a, b);
        assert_ne!(a, TRACE_NONE);
        {
            let _g = with_trace(a);
            assert_eq!(current_trace(), a);
            {
                let _g2 = with_trace(b);
                assert_eq!(current_trace(), b);
            }
            assert_eq!(current_trace(), a);
            // ensure_trace keeps an existing pin.
            let _g3 = ensure_trace();
            assert_eq!(current_trace(), a);
        }
        assert_eq!(current_trace(), TRACE_NONE);
        // ensure_trace mints when unpinned.
        let g = ensure_trace();
        assert_ne!(current_trace(), TRACE_NONE);
        drop(g);
        assert_eq!(current_trace(), TRACE_NONE);
    }

    #[cfg(feature = "obs")]
    #[test]
    fn global_recording_respects_the_kill_switch() {
        // Serialized against other tests by using distinctive labels:
        // the global recorder is process-wide.
        set_enabled(true);
        let tr = mint_trace();
        {
            let _g = with_trace(tr);
            span(SpanKind::Batch, "kill-switch-on", Duration::from_micros(5));
        }
        set_enabled(false);
        span_for(tr, SpanKind::Batch, "kill-switch-off", Duration::ZERO);
        set_enabled(true);
        let snap = snapshot();
        assert!(snap
            .iter()
            .any(|e| e.label() == "kill-switch-on" && e.trace_id == tr));
        assert!(!snap.iter().any(|e| e.label() == "kill-switch-off"));
    }

    #[cfg(not(feature = "obs"))]
    #[test]
    fn compiled_out_recording_is_inert() {
        set_enabled(true);
        assert!(!enabled());
        span(SpanKind::Batch, "never", Duration::from_secs(1));
        assert!(snapshot().is_empty());
        clear();
    }
}

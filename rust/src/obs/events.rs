//! Structured event journal: leveled, rate-limited, trace-stamped.
//!
//! Spans ([`crate::obs`]) answer "where did this request spend its
//! time"; the journal answers "what did the system *decide* and what
//! went wrong while it ran". Every operationally significant moment —
//! a malformed cost sidecar, decode degrading to inline, an eviction,
//! a shed request, a worker death and its attributed cause, a watchdog
//! anomaly — is one JSONL line:
//!
//! ```json
//! {"ts_ns":1723111575000000000,"seq":17,"level":"warn",
//!  "kind":"worker_exit","pid":4242,"trace_id":"0x0",
//!  "msg":"shard worker 1 exited","fields":{"cause":"signal 9"}}
//! ```
//!
//! Properties the serving path relies on:
//!
//! * **Bounded**: the journal keeps the newest
//!   [`DEFAULT_RING_CAPACITY`] rendered lines in memory; a file sink
//!   ([`set_sink_path`], `serve --events-out`) additionally appends
//!   every line as it is emitted and flushes per line, so a crash
//!   loses at most the line being written — the journal needs no
//!   graceful teardown to be useful.
//! * **Rate-limited**: each event kind has a token bucket
//!   ([`RATE_BURST`] burst, [`RATE_PER_SEC`] steady-state) so an
//!   eviction storm cannot turn the journal into the hot path.
//!   `error`-level events bypass the limiter; drops are counted per
//!   kind and surfaced in [`stats`](totals).
//! * **Attributable**: every line carries the emitting thread's
//!   current trace id ([`crate::obs::current_trace`]), so a shed or
//!   evict decision cross-references the Chrome trace.
//! * **Mirrored**: `warn`/`error` lines also go to stderr (the
//!   behavior the `eprintln!` sites this journal replaced had) unless
//!   [`set_stderr_mirror`]`(false)` — `serve --quiet`.

use crate::sync::lock_unpoisoned;
use std::collections::VecDeque;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Rendered lines the in-memory ring retains (newest win).
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Token-bucket burst per event kind.
pub const RATE_BURST: u32 = 64;

/// Token-bucket steady-state refill per event kind, per second.
pub const RATE_PER_SEC: u32 = 16;

/// Event severity. `Error` bypasses the per-kind rate limiter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Operational decisions worth a record (evictions, sheds).
    Info,
    /// Degradations the system survived (malformed sidecar, inline
    /// decode fallback, a reaped worker).
    Warn,
    /// Failures that cost a request or a subsystem.
    Error,
}

impl Level {
    /// Stable lowercase name (the JSON `level` field).
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }
}

/// One structured field value. Numbers stay numbers in the JSON.
#[derive(Debug, Clone)]
pub enum Value {
    /// Unsigned counter / byte count / nanoseconds.
    U64(u64),
    /// Measured or derived quantity.
    F64(f64),
    /// Free text (escaped on render).
    Str(String),
    /// Flag.
    Bool(bool),
}

impl Value {
    fn render(&self, out: &mut String) {
        match self {
            Value::U64(v) => out.push_str(&v.to_string()),
            Value::F64(v) if v.is_finite() => {
                out.push_str(&v.to_string())
            }
            Value::F64(_) => out.push('0'),
            Value::Str(s) => {
                out.push('"');
                escape_into(s, out);
                out.push('"');
            }
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        }
    }
}

/// Per-kind token bucket + drop counter.
struct KindBucket {
    kind: String,
    tokens: f64,
    last_refill: Instant,
    dropped: u64,
}

struct JournalInner {
    ring: VecDeque<String>,
    capacity: usize,
    sink: Option<std::fs::File>,
    buckets: Vec<KindBucket>,
    seq: u64,
    emitted: u64,
    dropped: u64,
}

/// A leveled, rate-limited JSONL event journal. One process-global
/// instance serves the crate ([`emit`] and friends); standalone
/// instances exist for tests.
pub struct Journal {
    inner: Mutex<JournalInner>,
    mirror: AtomicBool,
}

/// Journal counters: `(emitted, dropped_by_rate_limit)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Totals {
    /// Lines that made it into the ring (and sink, if any).
    pub emitted: u64,
    /// Events the per-kind rate limiter discarded.
    pub dropped: u64,
}

impl Journal {
    /// A journal retaining the newest `capacity` lines.
    pub fn new(capacity: usize) -> Journal {
        Journal {
            inner: Mutex::new(JournalInner {
                ring: VecDeque::new(),
                capacity: capacity.max(1),
                sink: None,
                buckets: Vec::new(),
                seq: 0,
                emitted: 0,
                dropped: 0,
            }),
            mirror: AtomicBool::new(true),
        }
    }

    /// Emit one event. Returns `false` when the rate limiter dropped
    /// it (`Error` level is never dropped).
    pub fn emit(
        &self,
        level: Level,
        kind: &str,
        msg: &str,
        fields: &[(&str, Value)],
    ) -> bool {
        {
            let mut inner = lock_unpoisoned(&self.inner);
            if level != Level::Error && !inner.admit(kind) {
                inner.dropped += 1;
                return false;
            }
            inner.seq += 1;
            inner.emitted += 1;
            let line =
                render_line(inner.seq, level, kind, msg, fields);
            if let Some(f) = inner.sink.as_mut() {
                // Best-effort append: a full disk must never take the
                // serving path down with it.
                let _ = f.write_all(line.as_bytes());
                let _ = f.write_all(b"\n");
                let _ = f.flush();
            }
            if inner.ring.len() >= inner.capacity {
                inner.ring.pop_front();
            }
            inner.ring.push_back(line);
        }
        if level != Level::Info && self.mirror.load(Ordering::Relaxed)
        {
            eprintln!("{msg}");
        }
        true
    }

    /// Mirror `warn`/`error` messages to stderr (default on; `serve
    /// --quiet` turns it off).
    pub fn set_stderr_mirror(&self, on: bool) {
        self.mirror.store(on, Ordering::Relaxed);
    }

    /// Route every subsequent line to a JSONL file as well (created or
    /// truncated now; each line is flushed as it is written).
    pub fn set_sink_path(&self, path: &Path) -> std::io::Result<()> {
        let file = std::fs::File::create(path)?;
        lock_unpoisoned(&self.inner).sink = Some(file);
        Ok(())
    }

    /// The newest `max` rendered lines, oldest first.
    pub fn recent(&self, max: usize) -> Vec<String> {
        let inner = lock_unpoisoned(&self.inner);
        let skip = inner.ring.len().saturating_sub(max);
        inner.ring.iter().skip(skip).cloned().collect()
    }

    /// Emitted / rate-dropped counters.
    pub fn totals(&self) -> Totals {
        let inner = lock_unpoisoned(&self.inner);
        Totals { emitted: inner.emitted, dropped: inner.dropped }
    }
}

impl JournalInner {
    /// Take one token from `kind`'s bucket, refilling by elapsed time.
    fn admit(&mut self, kind: &str) -> bool {
        let now = Instant::now();
        let bucket = match self
            .buckets
            .iter_mut()
            .find(|b| b.kind == kind)
        {
            Some(b) => b,
            None => {
                self.buckets.push(KindBucket {
                    kind: kind.to_string(),
                    tokens: RATE_BURST as f64,
                    last_refill: now,
                    dropped: 0,
                });
                match self.buckets.last_mut() {
                    Some(b) => b,
                    None => return true,
                }
            }
        };
        let dt = now
            .saturating_duration_since(bucket.last_refill)
            .as_secs_f64();
        bucket.last_refill = now;
        bucket.tokens = (bucket.tokens + dt * RATE_PER_SEC as f64)
            .min(RATE_BURST as f64);
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            true
        } else {
            bucket.dropped += 1;
            false
        }
    }
}

fn render_line(
    seq: u64,
    level: Level,
    kind: &str,
    msg: &str,
    fields: &[(&str, Value)],
) -> String {
    let mut out = String::with_capacity(128 + msg.len());
    out.push_str("{\"ts_ns\":");
    out.push_str(&super::unix_now_ns().to_string());
    out.push_str(",\"seq\":");
    out.push_str(&seq.to_string());
    out.push_str(",\"level\":\"");
    out.push_str(level.as_str());
    out.push_str("\",\"kind\":\"");
    escape_into(kind, &mut out);
    out.push_str("\",\"pid\":");
    out.push_str(&std::process::id().to_string());
    out.push_str(",\"trace_id\":\"");
    out.push_str(&format!("{:#x}", super::current_trace()));
    out.push_str("\",\"msg\":\"");
    escape_into(msg, &mut out);
    out.push('"');
    if !fields.is_empty() {
        out.push_str(",\"fields\":{");
        for (i, (k, v)) in fields.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('"');
            escape_into(k, &mut out);
            out.push_str("\":");
            v.render(&mut out);
        }
        out.push('}');
    }
    out.push('}');
    out
}

/// Minimal JSON string escaper, shared with the other obs emitters.
pub(crate) fn escape_into(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// The process-global journal every convenience function below uses.
pub fn global() -> &'static Journal {
    static GLOBAL: OnceLock<Journal> = OnceLock::new();
    GLOBAL.get_or_init(|| Journal::new(DEFAULT_RING_CAPACITY))
}

/// Emit one event on the global journal.
pub fn emit(
    level: Level,
    kind: &str,
    msg: &str,
    fields: &[(&str, Value)],
) -> bool {
    global().emit(level, kind, msg, fields)
}

/// `info`-level event on the global journal.
pub fn info(kind: &str, msg: &str, fields: &[(&str, Value)]) -> bool {
    emit(Level::Info, kind, msg, fields)
}

/// `warn`-level event on the global journal.
pub fn warn(kind: &str, msg: &str, fields: &[(&str, Value)]) -> bool {
    emit(Level::Warn, kind, msg, fields)
}

/// `error`-level event on the global journal (never rate-dropped).
pub fn error(kind: &str, msg: &str, fields: &[(&str, Value)]) -> bool {
    emit(Level::Error, kind, msg, fields)
}

/// Mirror toggle on the global journal (`serve --quiet` → false).
pub fn set_stderr_mirror(on: bool) {
    global().set_stderr_mirror(on);
}

/// File sink on the global journal (`serve --events-out`).
pub fn set_sink_path(path: &Path) -> std::io::Result<()> {
    global().set_sink_path(path)
}

/// Newest `max` lines from the global journal, oldest first.
pub fn recent(max: usize) -> Vec<String> {
    global().recent(max)
}

/// Counters of the global journal.
pub fn totals() -> Totals {
    global().totals()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lines_are_json_with_level_kind_and_fields() {
        let j = Journal::new(16);
        j.set_stderr_mirror(false);
        assert!(j.emit(
            Level::Warn,
            "unit_kind",
            "something \"quoted\"\nhappened",
            &[
                ("count", Value::U64(3)),
                ("rate", Value::F64(0.5)),
                ("layer", Value::Str("fc0".into())),
                ("degraded", Value::Bool(true)),
            ],
        ));
        let lines = j.recent(10);
        assert_eq!(lines.len(), 1);
        let line = &lines[0];
        assert!(line.starts_with("{\"ts_ns\":"), "{line}");
        assert!(line.ends_with('}'), "{line}");
        assert!(line.contains("\"level\":\"warn\""), "{line}");
        assert!(line.contains("\"kind\":\"unit_kind\""), "{line}");
        assert!(
            line.contains("something \\\"quoted\\\"\\nhappened"),
            "{line}"
        );
        assert!(line.contains("\"count\":3"), "{line}");
        assert!(line.contains("\"rate\":0.5"), "{line}");
        assert!(line.contains("\"layer\":\"fc0\""), "{line}");
        assert!(line.contains("\"degraded\":true"), "{line}");
        assert_eq!(
            j.totals(),
            Totals { emitted: 1, dropped: 0 }
        );
    }

    #[test]
    fn ring_keeps_the_newest_lines() {
        let j = Journal::new(4);
        j.set_stderr_mirror(false);
        for i in 0..10 {
            // Distinct kinds dodge the rate limiter entirely.
            j.emit(Level::Info, &format!("k{i}"), &format!("m{i}"), &[]);
        }
        let lines = j.recent(100);
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("\"msg\":\"m6\""));
        assert!(lines[3].contains("\"msg\":\"m9\""));
        assert_eq!(j.recent(2).len(), 2);
        assert!(j.recent(2)[1].contains("\"msg\":\"m9\""));
    }

    #[test]
    fn rate_limiter_drops_bursts_but_not_errors() {
        let j = Journal::new(1024);
        j.set_stderr_mirror(false);
        let mut admitted = 0;
        for _ in 0..(RATE_BURST * 3) {
            if j.emit(Level::Info, "storm", "evict", &[]) {
                admitted += 1;
            }
        }
        assert!(admitted >= RATE_BURST, "burst admitted");
        assert!(
            admitted < RATE_BURST * 3,
            "steady flood must be limited (admitted {admitted})"
        );
        let t = j.totals();
        assert_eq!(t.emitted, u64::from(admitted));
        assert!(t.dropped > 0);
        // Errors bypass the exhausted bucket.
        assert!(j.emit(Level::Error, "storm", "fatal", &[]));
        // A different kind has its own bucket.
        assert!(j.emit(Level::Info, "calm", "ok", &[]));
    }

    #[test]
    fn sink_receives_every_line_incrementally() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "f2f-events-test-{}.jsonl",
            std::process::id()
        ));
        let j = Journal::new(8);
        j.set_stderr_mirror(false);
        j.set_sink_path(&path).unwrap();
        j.emit(Level::Info, "a", "first", &[]);
        j.emit(Level::Warn, "b", "second", &[]);
        // No teardown: the sink is already flushed line by line.
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> =
            text.lines().filter(|l| !l.is_empty()).collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"msg\":\"first\""));
        assert!(lines[1].contains("\"msg\":\"second\""));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn trace_id_is_stamped_from_the_current_context() {
        let j = Journal::new(8);
        j.set_stderr_mirror(false);
        let tr = crate::obs::mint_trace();
        {
            let _g = crate::obs::with_trace(tr);
            j.emit(Level::Info, "traced", "inside", &[]);
        }
        let line = j.recent(1).remove(0);
        assert!(
            line.contains(&format!("\"trace_id\":\"{tr:#x}\"")),
            "{line}"
        );
    }
}

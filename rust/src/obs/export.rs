//! Chrome trace-event export: recorded spans → `chrome://tracing` JSON.
//!
//! The [trace-event format] is the lowest-common-denominator timeline
//! format both `chrome://tracing` and Perfetto load directly: a JSON
//! object with a `traceEvents` array of complete (`"ph": "X"`) and
//! instant (`"ph": "i"`) events, grouped by `pid`/`tid`. Each process
//! in a multi-process serve (router + every `shard-worker`) becomes
//! one pid lane, named via `process_name` metadata events; within a
//! lane, events render on a tid per [`SpanKind`] so queueing, GEMV,
//! decode and cache activity stack as separate tracks. `trace_id` and
//! the layer label ride in `args`, so selecting one request's spans is
//! a search for its (hex) trace id across every lane.
//!
//! Timestamps: [`super::SpanEvent::t_start_ns`] is wall-clock unix
//! nanoseconds precisely so lanes from different processes align; the
//! exporter rebases everything onto the earliest event to keep the
//! microsecond values small (trace-event `ts` is a double — raw unix
//! nanoseconds would cost sub-microsecond precision).
//!
//! [trace-event format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

use super::{SpanEvent, SpanKind};

/// One process's worth of recorded events: a pid lane in the export.
#[derive(Debug, Clone)]
pub struct ProcessLane {
    /// Operating-system process id (the lane key).
    pub pid: u32,
    /// Human-readable lane name (e.g. `router`, `shard-worker 1`).
    pub name: String,
    /// Events recorded by that process.
    pub events: Vec<SpanEvent>,
}

/// Render lanes as a Chrome trace-event JSON document.
pub fn chrome_trace(lanes: &[ProcessLane]) -> String {
    let t0 = lanes
        .iter()
        .flat_map(|l| l.events.iter().map(|e| e.t_start_ns))
        .min()
        .unwrap_or(0);
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |s: String, first: &mut bool| {
        if !*first {
            out.push_str(",\n");
        }
        *first = false;
        out.push_str(&s);
    };
    for lane in lanes {
        push(
            format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\
                 \"tid\":0,\"args\":{{\"name\":\"{}\"}}}}",
                lane.pid,
                escape(&lane.name)
            ),
            &mut first,
        );
        for ev in &lane.events {
            push(render_event(lane.pid, ev, t0), &mut first);
        }
    }
    out.push_str("\n]}\n");
    out
}

fn render_event(pid: u32, ev: &SpanEvent, t0: u64) -> String {
    let ts_us = ev.t_start_ns.saturating_sub(t0) as f64 / 1_000.0;
    let dur_us = ev.dur_ns as f64 / 1_000.0;
    let tid = ev.kind.as_u8();
    let args = format!(
        "{{\"trace_id\":\"{:#x}\",\"label\":\"{}\"}}",
        ev.trace_id,
        escape(ev.label())
    );
    if ev.kind.is_instant() && ev.dur_ns == 0 {
        // Thread-scoped instant: renders as a tick mark on the lane.
        format!(
            "{{\"name\":\"{}\",\"cat\":\"f2f\",\"ph\":\"i\",\"s\":\"t\",\
             \"pid\":{pid},\"tid\":{tid},\"ts\":{ts_us},\"args\":{args}}}",
            ev.kind.name()
        )
    } else {
        format!(
            "{{\"name\":\"{}\",\"cat\":\"f2f\",\"ph\":\"X\",\
             \"pid\":{pid},\"tid\":{tid},\"ts\":{ts_us},\
             \"dur\":{dur_us},\"args\":{args}}}",
            ev.kind.name()
        )
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        trace: u64,
        kind: SpanKind,
        label: &str,
        start: u64,
        dur: u64,
    ) -> SpanEvent {
        SpanEvent::new(trace, kind, label, start, dur)
    }

    #[test]
    fn lanes_render_as_pids_with_metadata_names() {
        let lanes = [
            ProcessLane {
                pid: 100,
                name: "router".into(),
                events: vec![
                    ev(7, SpanKind::Batch, "", 2_000, 900),
                    ev(7, SpanKind::Gemv, "mlp/fc0", 2_100, 300),
                    ev(7, SpanKind::CacheMiss, "mlp/fc0", 2_050, 0),
                ],
            },
            ProcessLane {
                pid: 200,
                name: "shard-worker 0".into(),
                events: vec![ev(7, SpanKind::Decode, "mlp/fc0", 2_200, 400)],
            },
        ];
        let json = chrome_trace(&lanes);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"pid\":100"));
        assert!(json.contains("\"pid\":200"));
        assert!(json.contains("\"process_name\""));
        assert!(json.contains("\"router\""));
        assert!(json.contains("\"shard-worker 0\""));
        // Complete spans carry dur; instants use ph:"i".
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ph\":\"i\""));
        // One request's spans are findable by trace id across lanes.
        assert_eq!(json.matches("\"trace_id\":\"0x7\"").count(), 4);
        // Timestamps rebase onto the earliest event (2_000 ns → 0 µs).
        assert!(json.contains("\"ts\":0"));
        // Cheap structural sanity: balanced brackets/braces.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn empty_export_is_still_well_formed() {
        let json = chrome_trace(&[]);
        assert!(json.contains("\"traceEvents\""));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn labels_and_names_are_escaped() {
        let lanes = [ProcessLane {
            pid: 1,
            name: "we\"ird\\lane".into(),
            events: vec![ev(1, SpanKind::Gemv, "a\"b", 0, 1)],
        }];
        let json = chrome_trace(&lanes);
        assert!(json.contains("we\\\"ird\\\\lane"));
        assert!(json.contains("a\\\"b"));
    }
}

//! Regression watchdog: rolling baselines over live latency signals,
//! `anomaly` journal events on sustained regression.
//!
//! The cost model already prices every layer (`decode_ns`/`gemv_ns`
//! EWMAs) and the server tracks request quantiles — but nothing
//! *watches* them. The watchdog closes the loop: a monitor thread
//! samples those signals every interval, folds each into a rolling
//! EWMA baseline, and when a signal stays above `factor ×` its
//! baseline for `sustain` consecutive samples it emits one
//! [`anomaly`](crate::obs::events) event naming the metric, the
//! current value, and the baseline it violated. ROADMAP item 5's
//! admission control consumes exactly this stream: "decode on
//! `mlp/fc2` is 3× its baseline" is the signal that batching and
//! shedding decisions need, delivered on the journal (and therefore
//! over the stats socket and `--events-out`) rather than in a
//! post-hoc export.
//!
//! The detector itself ([`BaselineTracker`]) is pure and synchronous
//! so tests drive it without threads or clocks.

use super::events::{self, Value};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Watchdog tuning.
#[derive(Debug, Clone, Copy)]
pub struct WatchdogConfig {
    /// Sampling cadence of the monitor thread.
    pub interval: Duration,
    /// A sample regresses when it exceeds `factor ×` the baseline.
    pub factor: f64,
    /// Consecutive regressed samples before an anomaly fires.
    pub sustain: u32,
    /// EWMA weight of a healthy sample when folding the baseline.
    pub alpha: f64,
}

impl Default for WatchdogConfig {
    fn default() -> WatchdogConfig {
        WatchdogConfig {
            interval: Duration::from_millis(500),
            factor: 2.0,
            sustain: 3,
            alpha: 0.2,
        }
    }
}

/// A fired anomaly: the sample and the baseline it violated.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Anomaly {
    /// The regressed sample value.
    pub current: f64,
    /// The rolling baseline at firing time.
    pub baseline: f64,
}

/// Rolling-EWMA regression detector for one scalar signal. Pure:
/// feed samples with [`observe`](BaselineTracker::observe), get an
/// [`Anomaly`] back when the regression has sustained.
#[derive(Debug, Clone)]
pub struct BaselineTracker {
    factor: f64,
    sustain: u32,
    alpha: f64,
    baseline: Option<f64>,
    streak: u32,
}

impl BaselineTracker {
    /// A fresh tracker with `cfg`'s thresholds and no baseline yet.
    pub fn new(cfg: &WatchdogConfig) -> BaselineTracker {
        BaselineTracker {
            factor: cfg.factor.max(1.0),
            sustain: cfg.sustain.max(1),
            alpha: cfg.alpha.clamp(0.0, 1.0),
            baseline: None,
            streak: 0,
        }
    }

    /// The current rolling baseline (`None` until the first positive
    /// sample).
    pub fn baseline(&self) -> Option<f64> {
        self.baseline
    }

    /// Feed one sample. Non-positive / non-finite samples (no data
    /// yet) are ignored. Healthy samples fold into the baseline;
    /// regressed samples extend the streak; the `sustain`-th
    /// consecutive regression fires an [`Anomaly`] and then folds the
    /// regressed value in so a genuine new plateau re-baselines
    /// instead of firing forever.
    pub fn observe(&mut self, value: f64) -> Option<Anomaly> {
        if !value.is_finite() || value <= 0.0 {
            return None;
        }
        let baseline = match self.baseline {
            Some(b) => b,
            None => {
                self.baseline = Some(value);
                return None;
            }
        };
        if value > baseline * self.factor {
            self.streak += 1;
            if self.streak >= self.sustain {
                self.streak = 0;
                self.baseline = Some(
                    baseline * (1.0 - self.alpha) + value * self.alpha,
                );
                return Some(Anomaly { current: value, baseline });
            }
        } else {
            self.streak = 0;
            self.baseline = Some(
                baseline * (1.0 - self.alpha) + value * self.alpha,
            );
        }
        None
    }
}

/// One sample of every signal the watchdog tracks.
#[derive(Debug, Clone, Default)]
pub struct WatchdogSample {
    /// Request p99 latency in nanoseconds (0 = no requests yet).
    pub request_p99_ns: f64,
    /// Per-layer `(name, decode_ns, gemv_ns)` EWMA estimates.
    pub layers: Vec<(String, f64, f64)>,
}

/// Per-signal tracker table, anomaly emission on the journal. Pure
/// apart from the journal write; the thread wrapper below drives it.
struct Detector {
    cfg: WatchdogConfig,
    request: BaselineTracker,
    layers: Vec<(String, BaselineTracker, BaselineTracker)>,
}

impl Detector {
    fn new(cfg: WatchdogConfig) -> Detector {
        Detector {
            request: BaselineTracker::new(&cfg),
            layers: Vec::new(),
            cfg,
        }
    }

    fn ingest(&mut self, sample: &WatchdogSample) {
        if let Some(a) = self.request.observe(sample.request_p99_ns) {
            emit_anomaly("request_p99_ns", "", &a);
        }
        for (name, decode_ns, gemv_ns) in &sample.layers {
            let slot = match self
                .layers
                .iter_mut()
                .find(|(n, _, _)| n == name)
            {
                Some(s) => s,
                None => {
                    self.layers.push((
                        name.clone(),
                        BaselineTracker::new(&self.cfg),
                        BaselineTracker::new(&self.cfg),
                    ));
                    match self.layers.last_mut() {
                        Some(s) => s,
                        None => continue,
                    }
                }
            };
            if let Some(a) = slot.1.observe(*decode_ns) {
                emit_anomaly("decode_ns", name, &a);
            }
            if let Some(a) = slot.2.observe(*gemv_ns) {
                emit_anomaly("gemv_ns", name, &a);
            }
        }
    }
}

fn emit_anomaly(metric: &str, layer: &str, a: &Anomaly) {
    let msg = if layer.is_empty() {
        format!(
            "watchdog: {metric} regressed to {:.0} (baseline {:.0})",
            a.current, a.baseline
        )
    } else {
        format!(
            "watchdog: {metric} on {layer} regressed to {:.0} (baseline {:.0})",
            a.current, a.baseline
        )
    };
    events::warn(
        "anomaly",
        &msg,
        &[
            ("metric", Value::Str(metric.to_string())),
            ("layer", Value::Str(layer.to_string())),
            ("current", Value::F64(a.current)),
            ("baseline", Value::F64(a.baseline)),
        ],
    );
}

/// The monitor thread. Dropping (or [`stop`](Watchdog::stop)ping) it
/// joins the thread.
pub struct Watchdog {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Watchdog {
    /// Start sampling `source` every `cfg.interval`, emitting
    /// `anomaly` journal events on sustained regressions.
    pub fn start<F>(cfg: WatchdogConfig, source: F) -> Watchdog
    where
        F: Fn() -> WatchdogSample + Send + 'static,
    {
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = Arc::clone(&stop);
            let interval = cfg.interval.max(Duration::from_millis(10));
            std::thread::Builder::new()
                .name("f2f-watchdog".into())
                .spawn(move || {
                    let mut det = Detector::new(cfg);
                    let tick = Duration::from_millis(10);
                    let mut since = Duration::ZERO;
                    while !stop.load(Ordering::Acquire) {
                        std::thread::sleep(tick);
                        since += tick;
                        if since >= interval {
                            since = Duration::ZERO;
                            det.ingest(&source());
                        }
                    }
                })
                .ok()
        };
        Watchdog { stop, thread }
    }

    /// Stop and join the monitor thread.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> WatchdogConfig {
        WatchdogConfig {
            interval: Duration::from_millis(10),
            factor: 2.0,
            sustain: 3,
            alpha: 0.2,
        }
    }

    #[test]
    fn steady_signal_never_fires() {
        let mut t = BaselineTracker::new(&cfg());
        for _ in 0..100 {
            assert_eq!(t.observe(1000.0), None);
        }
        // Mild drift folds into the baseline without firing.
        for i in 0..50 {
            assert_eq!(t.observe(1000.0 + f64::from(i) * 10.0), None);
        }
    }

    #[test]
    fn sustained_regression_fires_once_then_rebaselines() {
        let mut t = BaselineTracker::new(&cfg());
        for _ in 0..10 {
            t.observe(1000.0);
        }
        // Two regressed samples: below sustain, nothing fires.
        assert_eq!(t.observe(5000.0), None);
        assert_eq!(t.observe(5000.0), None);
        let fired = t.observe(5000.0).expect("third sample fires");
        assert_eq!(fired.current, 5000.0);
        assert!((fired.baseline - 1000.0).abs() < 1.0);
        // Baseline absorbed part of the spike; a return to normal
        // keeps quiet.
        assert!(t.baseline().unwrap() > 1000.0);
        for _ in 0..20 {
            assert_eq!(t.observe(1000.0), None);
        }
    }

    #[test]
    fn a_blip_resets_the_streak() {
        let mut t = BaselineTracker::new(&cfg());
        for _ in 0..10 {
            t.observe(1000.0);
        }
        assert_eq!(t.observe(5000.0), None);
        assert_eq!(t.observe(5000.0), None);
        assert_eq!(t.observe(1000.0), None, "healthy sample resets");
        assert_eq!(t.observe(5000.0), None);
        assert_eq!(t.observe(5000.0), None, "streak restarted from 0");
    }

    #[test]
    fn zero_and_nonfinite_samples_are_ignored() {
        let mut t = BaselineTracker::new(&cfg());
        assert_eq!(t.observe(0.0), None);
        assert_eq!(t.observe(-5.0), None);
        assert_eq!(t.observe(f64::NAN), None);
        assert_eq!(t.baseline(), None, "no baseline from junk");
        t.observe(100.0);
        assert_eq!(t.baseline(), Some(100.0));
        assert_eq!(t.observe(0.0), None);
        assert_eq!(t.baseline(), Some(100.0), "junk does not decay");
    }

    #[test]
    fn detector_emits_anomaly_events_per_layer_metric() {
        let mut det = Detector::new(cfg());
        let calm = WatchdogSample {
            request_p99_ns: 1_000_000.0,
            layers: vec![("wd/fc0".into(), 1000.0, 2000.0)],
        };
        for _ in 0..5 {
            det.ingest(&calm);
        }
        let hot = WatchdogSample {
            request_p99_ns: 1_000_000.0,
            layers: vec![("wd/fc0".into(), 9000.0, 2000.0)],
        };
        crate::obs::events::set_stderr_mirror(false);
        for _ in 0..3 {
            det.ingest(&hot);
        }
        let lines = crate::obs::events::recent(4096);
        let hit = lines.iter().any(|l| {
            l.contains("\"kind\":\"anomaly\"")
                && l.contains("\"layer\":\"wd/fc0\"")
                && l.contains("\"metric\":\"decode_ns\"")
        });
        assert!(hit, "anomaly event reached the journal");
        let gemv_hit = lines.iter().any(|l| {
            l.contains("\"layer\":\"wd/fc0\"")
                && l.contains("\"metric\":\"gemv_ns\"")
        });
        assert!(!gemv_hit, "healthy gemv signal stayed quiet");
    }

    #[test]
    fn watchdog_thread_starts_and_stops() {
        let wd = Watchdog::start(cfg(), WatchdogSample::default);
        std::thread::sleep(Duration::from_millis(40));
        wd.stop();
    }
}

//! Crash flight recorder: periodic checkpoints of the span ring and
//! event journal, surviving the process they describe.
//!
//! A worker's span ring lives in its own address space, so the one
//! moment it matters most — the worker just died — is exactly when
//! `TraceDump` over the wire can no longer reach it. The flight
//! recorder closes that hole: [`FlightRecorder::install`] registers a
//! panic hook and a checkpoint thread that atomically rewrite a small
//! binary sidecar, `<dir>/flight-<pid>.bin`, every interval (tmp file
//! + rename, so readers never see a torn write). When the
//! [`Supervisor`](crate::ipc::Supervisor) reaps a dead worker it
//! parses the sidecar ([`FlightData::read`]), attributes the exit, and
//! emits a postmortem artifact pair ([`write_postmortem`]): a Chrome
//! trace fragment of the worker's final spans plus a summary JSON with
//! the attributed cause, the panic message if any, and the tail of the
//! worker's event journal. A clean shutdown removes the sidecar — a
//! flight file left behind always means an unclean death.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! "F2FL" | u16 version | u32 pid | u64 wall_ns | u8 panicked
//! | u32 msg_len | msg | u32 n_events
//! | n × { u64 trace_id | u64 t_start_ns | u64 dur_ns
//!         | u8 kind | u8 label_len | label }
//! | u32 n_lines | n × { u32 len | line }
//! ```

use super::events::escape_into;
use super::{SpanEvent, SpanKind};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, Once, OnceLock};
use std::time::Duration;

/// Flight sidecar magic.
pub const FLIGHT_MAGIC: [u8; 4] = *b"F2FL";

/// Flight sidecar format version.
pub const FLIGHT_VERSION: u16 = 1;

/// Default checkpoint cadence. Short on purpose: the recorder exists
/// for the window between "traffic happened" and "worker died".
pub const DEFAULT_CHECKPOINT_INTERVAL: Duration = Duration::from_millis(100);

/// Newest span events a checkpoint retains.
pub const MAX_FLIGHT_EVENTS: usize = 8192;

/// Newest journal lines a checkpoint retains.
pub const MAX_FLIGHT_JOURNAL: usize = 256;

const MAX_MSG_BYTES: usize = 64 * 1024;
const MAX_LINE_BYTES: usize = 64 * 1024;
const EVENT_MIN_BYTES: usize = 26;
const LINE_MIN_BYTES: usize = 4;

/// One parsed flight checkpoint: the last observable state of a
/// (possibly dead) process.
#[derive(Debug, Clone)]
pub struct FlightData {
    /// Pid of the process that wrote the checkpoint.
    pub pid: u32,
    /// Wall-clock time of the checkpoint, ns since the unix epoch.
    pub wall_ns: u64,
    /// True when written from inside the panic hook.
    pub panicked: bool,
    /// The panic payload message (empty unless `panicked`).
    pub panic_msg: String,
    /// Newest span events at checkpoint time, start-ordered.
    pub events: Vec<SpanEvent>,
    /// Newest journal lines at checkpoint time, oldest first.
    pub journal: Vec<String>,
}

impl FlightData {
    /// Snapshot this process's span ring and journal tail.
    pub fn capture(panic_msg: Option<&str>) -> FlightData {
        let mut events = super::snapshot();
        let skip = events.len().saturating_sub(MAX_FLIGHT_EVENTS);
        if skip > 0 {
            events.drain(..skip);
        }
        FlightData {
            pid: std::process::id(),
            wall_ns: super::unix_now_ns(),
            panicked: panic_msg.is_some(),
            panic_msg: panic_msg.unwrap_or("").to_string(),
            events,
            journal: super::events::recent(MAX_FLIGHT_JOURNAL),
        }
    }

    /// Serialize to the sidecar format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            64 + self.events.len() * 64 + self.journal.len() * 64,
        );
        out.extend_from_slice(&FLIGHT_MAGIC);
        out.extend_from_slice(&FLIGHT_VERSION.to_le_bytes());
        out.extend_from_slice(&self.pid.to_le_bytes());
        out.extend_from_slice(&self.wall_ns.to_le_bytes());
        out.push(u8::from(self.panicked));
        let msg = trim_bytes(&self.panic_msg, MAX_MSG_BYTES);
        out.extend_from_slice(&(msg.len() as u32).to_le_bytes());
        out.extend_from_slice(msg.as_bytes());
        out.extend_from_slice(&(self.events.len() as u32).to_le_bytes());
        for ev in &self.events {
            out.extend_from_slice(&ev.trace_id.to_le_bytes());
            out.extend_from_slice(&ev.t_start_ns.to_le_bytes());
            out.extend_from_slice(&ev.dur_ns.to_le_bytes());
            out.push(ev.kind.as_u8());
            let label = ev.label();
            out.push(label.len() as u8);
            out.extend_from_slice(label.as_bytes());
        }
        out.extend_from_slice(&(self.journal.len() as u32).to_le_bytes());
        for line in &self.journal {
            let line = trim_bytes(line, MAX_LINE_BYTES);
            out.extend_from_slice(&(line.len() as u32).to_le_bytes());
            out.extend_from_slice(line.as_bytes());
        }
        out
    }

    /// Parse a sidecar. Fully bounds-checked: a torn or corrupt file
    /// errors, it never panics. Events with unknown kinds (a newer
    /// writer) are dropped individually.
    pub fn parse(bytes: &[u8]) -> Result<FlightData> {
        let mut c = Cursor { buf: bytes, at: 0 };
        if c.take(4)? != FLIGHT_MAGIC {
            bail!("flight sidecar: bad magic");
        }
        let version = c.u16()?;
        if version != FLIGHT_VERSION {
            bail!("flight sidecar: unsupported version {version}");
        }
        let pid = c.u32()?;
        let wall_ns = c.u64()?;
        let panicked = c.u8()? != 0;
        let msg_len = c.u32()? as usize;
        if msg_len > MAX_MSG_BYTES {
            bail!("flight sidecar: panic message of {msg_len} bytes");
        }
        let panic_msg =
            String::from_utf8_lossy(c.take(msg_len)?).into_owned();
        let n_events = c.u32()? as usize;
        if n_events > c.remaining() / EVENT_MIN_BYTES {
            bail!("flight sidecar: event count {n_events} exceeds payload");
        }
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            let trace_id = c.u64()?;
            let t_start_ns = c.u64()?;
            let dur_ns = c.u64()?;
            let kind = c.u8()?;
            let label_len = c.u8()? as usize;
            if label_len > super::MAX_LABEL_BYTES {
                bail!("flight sidecar: label of {label_len} bytes");
            }
            let label =
                String::from_utf8_lossy(c.take(label_len)?).into_owned();
            if let Some(kind) = SpanKind::from_u8(kind) {
                events.push(SpanEvent::new(
                    trace_id, kind, &label, t_start_ns, dur_ns,
                ));
            }
        }
        let n_lines = c.u32()? as usize;
        if n_lines > c.remaining() / LINE_MIN_BYTES {
            bail!("flight sidecar: line count {n_lines} exceeds payload");
        }
        let mut journal = Vec::with_capacity(n_lines);
        for _ in 0..n_lines {
            let len = c.u32()? as usize;
            if len > MAX_LINE_BYTES {
                bail!("flight sidecar: journal line of {len} bytes");
            }
            journal
                .push(String::from_utf8_lossy(c.take(len)?).into_owned());
        }
        Ok(FlightData {
            pid,
            wall_ns,
            panicked,
            panic_msg,
            events,
            journal,
        })
    }

    /// Read and parse a sidecar file.
    pub fn read(path: &Path) -> Result<FlightData> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read {}", path.display()))?;
        FlightData::parse(&bytes)
            .with_context(|| format!("parse {}", path.display()))
    }
}

/// The sidecar path a process with `pid` checkpoints into under `dir`.
pub fn flight_path(dir: &Path, pid: u32) -> PathBuf {
    dir.join(format!("flight-{pid}.bin"))
}

fn trim_bytes(s: &str, max: usize) -> &str {
    let mut n = s.len().min(max);
    while n > 0 && !s.is_char_boundary(n) {
        n -= 1;
    }
    s.get(..n).unwrap_or("")
}

/// Bounds-checked reader over untrusted sidecar bytes.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        match self.buf.get(self.at..self.at.saturating_add(n)) {
            Some(s) => {
                self.at += n;
                Ok(s)
            }
            None => bail!("flight sidecar: truncated at byte {}", self.at),
        }
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?.first().copied().unwrap_or(0))
    }

    fn u16(&mut self) -> Result<u16> {
        let mut b = [0u8; 2];
        b.copy_from_slice(self.take(2)?);
        Ok(u16::from_le_bytes(b))
    }

    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        b.copy_from_slice(self.take(4)?);
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        b.copy_from_slice(self.take(8)?);
        Ok(u64::from_le_bytes(b))
    }

    fn remaining(&self) -> usize {
        self.buf.len().saturating_sub(self.at)
    }
}

// ---------------------------------------------------------------------
// Recorder: checkpoint thread + panic hook.
// ---------------------------------------------------------------------

/// Where the panic hook writes its final checkpoint. Process-global
/// because `std::panic::set_hook` is.
fn hook_path() -> &'static Mutex<Option<PathBuf>> {
    static PATH: OnceLock<Mutex<Option<PathBuf>>> = OnceLock::new();
    PATH.get_or_init(|| Mutex::new(None))
}

fn checkpoint(path: &Path, panic_msg: Option<&str>) {
    // Best effort by design: a full disk must not take the worker down.
    let data = FlightData::capture(panic_msg);
    let _ = super::write_atomic(path, &data.to_bytes());
}

fn install_panic_hook() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| {
                    info.payload().downcast_ref::<String>().cloned()
                })
                .unwrap_or_else(|| "<non-string panic payload>".into());
            let msg = match info.location() {
                Some(loc) => format!("{msg} at {loc}"),
                None => msg,
            };
            let path = crate::sync::lock_unpoisoned(hook_path()).clone();
            if let Some(path) = path {
                checkpoint(&path, Some(&msg));
            }
            prev(info);
        }));
    });
}

/// Periodic flight checkpointing for this process. Dropping the
/// recorder stops the thread but leaves the newest sidecar on disk
/// (crash-safe default); [`FlightRecorder::finish`]`(true)` is the
/// clean-shutdown path that removes it.
pub struct FlightRecorder {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
    path: PathBuf,
}

impl FlightRecorder {
    /// Start checkpointing into `dir` every `interval`. Writes an
    /// immediate first checkpoint and registers the process panic
    /// hook, so even a death right after install leaves a sidecar.
    pub fn install(dir: &Path, interval: Duration) -> Result<FlightRecorder> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create {}", dir.display()))?;
        let path = flight_path(dir, std::process::id());
        *crate::sync::lock_unpoisoned(hook_path()) = Some(path.clone());
        install_panic_hook();
        checkpoint(&path, None);
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = Arc::clone(&stop);
            let path = path.clone();
            let interval = interval.max(Duration::from_millis(10));
            std::thread::Builder::new()
                .name("f2f-flight".into())
                .spawn(move || {
                    let tick = Duration::from_millis(10);
                    let mut since = Duration::ZERO;
                    while !stop.load(Ordering::Acquire) {
                        std::thread::sleep(tick);
                        since += tick;
                        if since >= interval {
                            since = Duration::ZERO;
                            checkpoint(&path, None);
                        }
                    }
                })
                .ok()
        };
        Ok(FlightRecorder { stop, thread, path })
    }

    /// The sidecar path this recorder maintains.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Stop checkpointing. `clean` removes the sidecar (orderly
    /// shutdown — no forensics needed); otherwise a final checkpoint
    /// is written and the file stays.
    pub fn finish(mut self, clean: bool) {
        self.halt();
        if clean {
            *crate::sync::lock_unpoisoned(hook_path()) = None;
            let _ = std::fs::remove_file(&self.path);
        } else {
            checkpoint(&self.path, None);
        }
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for FlightRecorder {
    fn drop(&mut self) {
        self.halt();
    }
}

// ---------------------------------------------------------------------
// Postmortem artifacts (supervisor side).
// ---------------------------------------------------------------------

/// Paths of the artifact pair [`write_postmortem`] produced.
#[derive(Debug, Clone)]
pub struct Postmortem {
    /// Summary JSON: pid, attributed cause, span/journal counts,
    /// panic message, journal tail.
    pub summary_path: PathBuf,
    /// Chrome trace-event fragment of the dead process's final spans.
    pub trace_path: PathBuf,
    /// Span events carried into the trace fragment.
    pub spans: usize,
}

/// Render a dead worker's flight checkpoint into
/// `<dir>/postmortem-<pid>.json` + `<dir>/postmortem-<pid>.trace.json`.
/// `cause` is the supervisor's exit attribution (e.g. `"signal 9"`,
/// `"panic: …"`, `"clean exit"`).
pub fn write_postmortem(
    dir: &Path,
    data: &FlightData,
    cause: &str,
) -> Result<Postmortem> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create {}", dir.display()))?;
    let trace_path = dir.join(format!("postmortem-{}.trace.json", data.pid));
    let lane = super::ProcessLane {
        pid: data.pid,
        name: format!("flight pid {}", data.pid),
        events: data.events.clone(),
    };
    super::write_atomic(
        &trace_path,
        super::chrome_trace(&[lane]).as_bytes(),
    )?;
    let mut json = String::with_capacity(512);
    json.push_str("{\n  \"pid\": ");
    json.push_str(&data.pid.to_string());
    json.push_str(",\n  \"cause\": \"");
    escape_into(cause, &mut json);
    json.push_str("\",\n  \"panicked\": ");
    json.push_str(if data.panicked { "true" } else { "false" });
    json.push_str(",\n  \"panic_msg\": \"");
    escape_into(&data.panic_msg, &mut json);
    json.push_str("\",\n  \"checkpoint_wall_ns\": ");
    json.push_str(&data.wall_ns.to_string());
    json.push_str(",\n  \"spans\": ");
    json.push_str(&data.events.len().to_string());
    json.push_str(",\n  \"journal_lines\": ");
    json.push_str(&data.journal.len().to_string());
    json.push_str(",\n  \"trace\": \"");
    escape_into(
        trace_path.file_name().and_then(|n| n.to_str()).unwrap_or(""),
        &mut json,
    );
    json.push_str("\",\n  \"journal_tail\": [");
    let tail_skip = data.journal.len().saturating_sub(32);
    for (i, line) in data.journal.iter().skip(tail_skip).enumerate() {
        if i > 0 {
            json.push(',');
        }
        // Journal lines are themselves JSON objects: embed verbatim.
        json.push_str("\n    ");
        json.push_str(line);
    }
    json.push_str("\n  ]\n}\n");
    let summary_path = dir.join(format!("postmortem-{}.json", data.pid));
    super::write_atomic(&summary_path, json.as_bytes())?;
    Ok(Postmortem { summary_path, trace_path, spans: data.events.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> FlightData {
        FlightData {
            pid: 4242,
            wall_ns: 1_700_000_000_000_000_000,
            panicked: true,
            panic_msg: "boom at worker.rs:1".into(),
            events: vec![
                SpanEvent::new(7, SpanKind::Decode, "mlp/fc0", 100, 50),
                SpanEvent::new(7, SpanKind::Gemv, "mlp/fc1", 200, 25),
                SpanEvent::new(0, SpanKind::Evict, "mlp/fc2", 300, 0),
            ],
            journal: vec![
                "{\"kind\":\"a\"}".into(),
                "{\"kind\":\"b\"}".into(),
            ],
        }
    }

    #[test]
    fn sidecar_round_trips() {
        let data = sample();
        let parsed = FlightData::parse(&data.to_bytes()).unwrap();
        assert_eq!(parsed.pid, data.pid);
        assert_eq!(parsed.wall_ns, data.wall_ns);
        assert_eq!(parsed.panicked, data.panicked);
        assert_eq!(parsed.panic_msg, data.panic_msg);
        assert_eq!(parsed.events, data.events);
        assert_eq!(parsed.journal, data.journal);
    }

    #[test]
    fn corrupt_sidecars_error_instead_of_panicking() {
        let bytes = sample().to_bytes();
        assert!(FlightData::parse(b"").is_err());
        assert!(FlightData::parse(b"XXXX").is_err());
        // Truncation at every prefix length must error or parse, never
        // panic; short prefixes always error.
        for cut in 0..bytes.len().min(64) {
            assert!(
                FlightData::parse(&bytes[..cut]).is_err(),
                "prefix of {cut} bytes parsed"
            );
        }
        // A lying event count is rejected up front.
        let mut lying = bytes.clone();
        let n_events_at = 4 + 2 + 4 + 8 + 1 + 4 + sample().panic_msg.len();
        lying[n_events_at..n_events_at + 4]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(FlightData::parse(&lying).is_err());
    }

    #[test]
    fn unknown_span_kinds_are_dropped_individually() {
        let mut bytes = sample().to_bytes();
        // First event's kind byte: header + msg + n_events + 24.
        let kind_at =
            4 + 2 + 4 + 8 + 1 + 4 + sample().panic_msg.len() + 4 + 24;
        bytes[kind_at] = 250;
        let parsed = FlightData::parse(&bytes).unwrap();
        assert_eq!(parsed.events.len(), 2, "one event dropped");
        assert_eq!(parsed.events[0].kind, SpanKind::Gemv);
    }

    #[test]
    fn recorder_checkpoints_and_clean_finish_removes() {
        let dir = std::env::temp_dir()
            .join(format!("f2f-flight-test-{}", std::process::id()));
        let rec = FlightRecorder::install(
            &dir,
            Duration::from_millis(10),
        )
        .unwrap();
        let path = rec.path().to_path_buf();
        assert!(path.exists(), "initial checkpoint is immediate");
        let data = FlightData::read(&path).unwrap();
        assert_eq!(data.pid, std::process::id());
        assert!(!data.panicked);
        rec.finish(true);
        assert!(!path.exists(), "clean finish removes the sidecar");
        // Unclean finish leaves a final checkpoint behind.
        let rec =
            FlightRecorder::install(&dir, Duration::from_millis(10))
                .unwrap();
        rec.finish(false);
        assert!(path.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn postmortem_artifacts_carry_spans_and_cause() {
        let dir = std::env::temp_dir()
            .join(format!("f2f-postmortem-test-{}", std::process::id()));
        let data = sample();
        let pm = write_postmortem(&dir, &data, "signal 9").unwrap();
        assert_eq!(pm.spans, 3);
        let summary =
            std::fs::read_to_string(&pm.summary_path).unwrap();
        assert!(summary.contains("\"cause\": \"signal 9\""), "{summary}");
        assert!(summary.contains("\"pid\": 4242"), "{summary}");
        assert!(summary.contains("\"spans\": 3"), "{summary}");
        assert!(summary.contains("boom at worker.rs:1"), "{summary}");
        assert!(summary.contains("{\"kind\":\"b\"}"), "{summary}");
        let trace = std::fs::read_to_string(&pm.trace_path).unwrap();
        assert!(trace.contains("\"traceEvents\""), "{trace}");
        assert!(trace.contains("mlp/fc0"), "{trace}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

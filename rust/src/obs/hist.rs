//! `HdrLite`: a log-bucketed latency histogram, mergeable and wire-flat.
//!
//! The coordinator used to keep a raw reservoir of latency samples and
//! sort it on every snapshot — O(n log n) per scrape, a fixed memory
//! ceiling, and no way to merge two windows (per-shard, per-worker)
//! without shipping every sample. `HdrLite` replaces that with 64
//! power-of-two buckets over nanoseconds: recording is one `leading_zeros`
//! plus an increment, merging is element-wise addition, and the whole
//! histogram flattens to a fixed run of `u64`s for the wire `Metrics`
//! frame. Exact `min`/`max` ride along so tail percentiles of sparse
//! windows (one sample, two samples) report the *observed* extreme
//! instead of a bucket bound — the sort-free answer to the old
//! "p99 of a single sample is zero" edge case.
//!
//! Quantiles are bucket-resolution: `value_at(q)` returns the upper
//! bound of the bucket holding the rank-`q` sample, clamped into
//! `[min, max]`, so any reported percentile is within 2x of the true
//! sample (and exact at the extremes). That is plenty for SLO tracking
//! and trend diffing, and it is what makes the merge exact: merging two
//! histograms and querying is identical to recording every sample into
//! one.

use std::time::Duration;

/// Number of power-of-two buckets. Bucket `b > 0` covers
/// `[2^(b-1), 2^b - 1]` nanoseconds; bucket 0 holds exact zeros; the
/// last bucket is open-ended. 64 buckets span 1 ns to ~292 years.
pub const HDR_BUCKETS: usize = 64;

/// Log-bucketed latency histogram: 64 pow-2 buckets over nanoseconds,
/// exact min/max, element-wise mergeable, flattenable to `u64`s for
/// the wire. See the module docs for the accuracy contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HdrLite {
    counts: [u64; HDR_BUCKETS],
    total: u64,
    min_ns: u64,
    max_ns: u64,
}

impl Default for HdrLite {
    fn default() -> Self {
        HdrLite {
            counts: [0; HDR_BUCKETS],
            total: 0,
            min_ns: 0,
            max_ns: 0,
        }
    }
}

/// Flattened length of one histogram on the wire:
/// `total, min_ns, max_ns` followed by the bucket counts.
pub const HDR_WIRE_FIELDS: usize = 3 + HDR_BUCKETS;

fn bucket_of(v: u64) -> usize {
    // 0 → bucket 0; otherwise floor(log2(v)) + 1, saturating at the
    // open-ended last bucket.
    ((u64::BITS - v.leading_zeros()) as usize).min(HDR_BUCKETS - 1)
}

fn bucket_upper(b: usize) -> u64 {
    if b >= HDR_BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

impl HdrLite {
    /// An empty histogram.
    pub fn new() -> Self {
        HdrLite::default()
    }

    /// Record one duration (saturating at `u64::MAX` nanoseconds).
    pub fn record(&mut self, d: Duration) {
        self.record_ns(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Record one raw nanosecond value.
    pub fn record_ns(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        if self.total == 0 {
            self.min_ns = v;
            self.max_ns = v;
        } else {
            self.min_ns = self.min_ns.min(v);
            self.max_ns = self.max_ns.max(v);
        }
        self.total += 1;
    }

    /// Fold another histogram into this one. Querying the merge is
    /// identical to having recorded every sample into one histogram.
    pub fn merge(&mut self, other: &HdrLite) {
        if other.total == 0 {
            return;
        }
        if self.total == 0 {
            self.min_ns = other.min_ns;
            self.max_ns = other.max_ns;
        } else {
            self.min_ns = self.min_ns.min(other.min_ns);
            self.max_ns = self.max_ns.max(other.max_ns);
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Exact largest recorded value (zero when empty).
    pub fn max(&self) -> Duration {
        Duration::from_nanos(if self.total == 0 { 0 } else { self.max_ns })
    }

    /// Exact smallest recorded value (zero when empty).
    pub fn min(&self) -> Duration {
        Duration::from_nanos(if self.total == 0 { 0 } else { self.min_ns })
    }

    /// The value at quantile `q` (clamped into `[0, 1]`) in
    /// nanoseconds: the upper bound of the bucket holding the
    /// rank-`ceil(q·count)` sample, clamped into `[min, max]`. Zero
    /// only when the histogram is empty — a single-sample window
    /// reports that sample at every quantile.
    pub fn value_at(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let q = if q.is_finite() { q.clamp(0.0, 1.0) } else { 1.0 };
        let rank =
            ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper(b)
                    .min(self.max_ns)
                    .max(self.min_ns);
            }
        }
        self.max_ns
    }

    /// [`HdrLite::value_at`] as a [`Duration`].
    pub fn percentile(&self, q: f64) -> Duration {
        Duration::from_nanos(self.value_at(q))
    }

    /// Flatten for the wire: `total, min_ns, max_ns`, then the bucket
    /// counts — [`HDR_WIRE_FIELDS`] values.
    pub fn to_wire(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(HDR_WIRE_FIELDS);
        out.push(self.total);
        out.push(self.min_ns);
        out.push(self.max_ns);
        out.extend_from_slice(&self.counts);
        out
    }

    /// Rebuild from a wire flattening. Tolerant of short slices (a
    /// payload from an older peer): missing fields read as zero.
    pub fn from_wire(vals: &[u64]) -> HdrLite {
        let at = |i: usize| vals.get(i).copied().unwrap_or(0);
        let mut h = HdrLite {
            counts: [0; HDR_BUCKETS],
            total: at(0),
            min_ns: at(1),
            max_ns: at(2),
        };
        for (b, slot) in h.counts.iter_mut().enumerate() {
            *slot = at(3 + b);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn us(v: u64) -> Duration {
        Duration::from_micros(v)
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = HdrLite::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(0.5), Duration::ZERO);
        assert_eq!(h.percentile(0.99), Duration::ZERO);
        assert_eq!(h.max(), Duration::ZERO);
        assert_eq!(h.min(), Duration::ZERO);
    }

    #[test]
    fn single_sample_reports_itself_at_every_quantile() {
        let mut h = HdrLite::new();
        h.record(us(5_000));
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.percentile(q), us(5_000), "q={q}");
        }
        assert_eq!(h.min(), us(5_000));
        assert_eq!(h.max(), us(5_000));
    }

    #[test]
    fn two_samples_split_between_min_and_max() {
        let mut h = HdrLite::new();
        h.record(us(1_000));
        h.record(us(100_000));
        // p50 lands on the first sample's bucket (within 2x), p99 on
        // the exact max.
        let p50 = h.value_at(0.5);
        assert!(
            (500_000..=2_000_000).contains(&p50),
            "p50 within 2x of 1ms: {p50}ns"
        );
        assert_eq!(h.percentile(0.99), us(100_000), "p99 clamps to max");
        assert_eq!(h.percentile(1.0), us(100_000));
    }

    #[test]
    fn skewed_window_keeps_the_tail_visible() {
        // 99 fast samples and one 1 s outlier: p50/p99 stay near the
        // body, p100 reports the outlier exactly.
        let mut h = HdrLite::new();
        for _ in 0..99 {
            h.record(us(1_000));
        }
        h.record(Duration::from_secs(1));
        let p50 = h.value_at(0.5);
        assert!(p50 <= 2_000_000, "p50 near the body: {p50}ns");
        let p99 = h.value_at(0.99);
        assert!(p99 <= 2_000_000, "p99 is the 99th of 100: {p99}ns");
        assert_eq!(h.percentile(1.0), Duration::from_secs(1));
        assert_eq!(h.max(), Duration::from_secs(1));
    }

    #[test]
    fn quantiles_are_within_2x_and_monotone() {
        let mut h = HdrLite::new();
        for v in [100u64, 200, 300, 431, 1_024, 9_999, 65_536] {
            h.record_ns(v);
        }
        let mut prev = 0;
        for q in [0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let v = h.value_at(q);
            assert!(v >= prev, "monotone at q={q}");
            prev = v;
        }
        // Every reported quantile is a plausible sample bound.
        assert!(h.value_at(0.5) >= 100 && h.value_at(0.5) <= 65_536);
        assert_eq!(h.value_at(1.0), 65_536);
    }

    #[test]
    fn zero_values_land_in_bucket_zero() {
        let mut h = HdrLite::new();
        h.record_ns(0);
        h.record_ns(0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.value_at(0.99), 0);
        assert_eq!(h.max(), Duration::ZERO);
    }

    #[test]
    fn merge_matches_recording_into_one() {
        let samples_a = [120u64, 4_500, 88_000, 1_000_000];
        let samples_b = [60u64, 60, 9, 77_000_000];
        let mut a = HdrLite::new();
        let mut b = HdrLite::new();
        let mut all = HdrLite::new();
        for v in samples_a {
            a.record_ns(v);
            all.record_ns(v);
        }
        for v in samples_b {
            b.record_ns(v);
            all.record_ns(v);
        }
        a.merge(&b);
        assert_eq!(a, all);
        // Merging an empty histogram is a no-op in both directions.
        let mut empty = HdrLite::new();
        empty.merge(&a);
        assert_eq!(empty, all);
        a.merge(&HdrLite::new());
        assert_eq!(a, all);
    }

    #[test]
    fn wire_flattening_round_trips_and_tolerates_truncation() {
        let mut h = HdrLite::new();
        for v in [1u64, 2, 3, 500, 123_456_789] {
            h.record_ns(v);
        }
        let flat = h.to_wire();
        assert_eq!(flat.len(), HDR_WIRE_FIELDS);
        assert_eq!(HdrLite::from_wire(&flat), h);
        // A short payload (older peer) zero-fills the missing tail
        // instead of erroring.
        let short = HdrLite::from_wire(&flat[..10]);
        assert_eq!(short.count(), h.count());
        assert_eq!(short.max(), h.max());
        // An empty payload is an empty histogram.
        assert_eq!(HdrLite::from_wire(&[]), HdrLite::new());
    }

    #[test]
    fn hostile_quantiles_never_panic() {
        let mut h = HdrLite::new();
        h.record_ns(42);
        for q in [-1.0, 2.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let v = h.value_at(q);
            assert!(v == 42, "q={q} → {v}");
        }
    }
}

//! Streaming stats: live snapshots of a serving process, on demand.
//!
//! Everything PR 6 measures — request/batch histograms, per-store
//! cache counters, per-layer cost EWMAs — was only exported at
//! graceful teardown. This module turns those signals into a *live*
//! surface:
//!
//! * [`LiveSources`] — closures over the running server's metrics
//!   handle, queue gauges, stores and cost tables. Snapshots are
//!   taken on demand per request, so polling never pauses traffic:
//!   each source is a lock-snapshot the serving path already takes.
//! * [`LiveSources::stats_json`] — one self-describing JSON document
//!   (schema-versioned, objects and numbers only, so the same
//!   hardened reader that parses cost profiles parses it).
//! * [`StatsServer`] (unix) — a dedicated socket speaking the
//!   existing wire frames: `Metrics` answers the *merged*
//!   [`StoreMetrics`] across shards, `CostProfile` the merged cost
//!   table, `TraceDump` this process's span ring, `Stats` the JSON
//!   snapshot, `Events` the journal tail. `serve --stats-socket`
//!   starts one; `f2f top <socket>` polls it and renders
//!   [`StatsSnapshot::render`]'s refreshing table.

use super::events;
use super::watchdog::WatchdogSample;
use crate::coordinator::MetricsSnapshot;
use crate::report::Table;
use crate::shard::CostProfile;
use crate::store::{LayerCost, StoreMetrics};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Stats-document schema version ([`LiveSources::stats_json`]).
pub const STATS_SCHEMA: u64 = 1;

/// Hard cap on journal lines one `Events` request returns.
pub const MAX_EVENT_LINES: u32 = 65_536;

/// Source of the coordinator's [`MetricsSnapshot`].
pub type ServerSource = Arc<dyn Fn() -> MetricsSnapshot + Send + Sync>;

/// Source of the `(inflight, capacity)` queue gauge.
pub type QueueSource = Arc<dyn Fn() -> (usize, usize) + Send + Sync>;

/// Source of per-store `(name, metrics)` snapshots.
pub type StoresSource =
    Arc<dyn Fn() -> Vec<(String, StoreMetrics)> + Send + Sync>;

/// Source of merged per-layer `(name, cost)` estimates.
pub type CostsSource =
    Arc<dyn Fn() -> Vec<(String, LayerCost)> + Send + Sync>;

/// One zoo tenant's live view: its request window (per-model
/// [`MetricsSnapshot`]) plus its slice of the shared cache.
#[derive(Debug, Clone, Default)]
pub struct ModelLiveStats {
    /// Requests completed for this model.
    pub completed: u64,
    /// Requests failed for this model.
    pub errors: u64,
    /// Per-model request latency percentiles.
    pub p50: std::time::Duration,
    pub p99: std::time::Duration,
    /// Mean executed batch size (batches never mix models).
    pub mean_batch_size: f64,
    /// Layers the model's chain fetches per pass.
    pub chain_layers: u64,
    /// This model's currently resident layers / bytes in the shared
    /// store(s) (0 when residency is worker-side, i.e. over IPC).
    pub cached_layers: u64,
    pub cached_bytes: u64,
}

/// Source of per-model `(id, stats)` snapshots — attached when the
/// process serves a [`crate::registry::ModelRegistry`] zoo.
pub type ModelsSource =
    Arc<dyn Fn() -> Vec<(String, ModelLiveStats)> + Send + Sync>;

/// Live taps into a serving process. Every accessor snapshots *now* —
/// nothing is cached, nothing waits for teardown. Cloning shares the
/// underlying closures.
#[derive(Clone)]
pub struct LiveSources {
    server: Option<ServerSource>,
    queue: Option<QueueSource>,
    stores: StoresSource,
    costs: CostsSource,
    models: Option<ModelsSource>,
}

impl LiveSources {
    /// Sources over store metrics and a cost table (the minimum any
    /// serving process has).
    pub fn new(stores: StoresSource, costs: CostsSource) -> LiveSources {
        LiveSources {
            server: None,
            queue: None,
            stores,
            costs,
            models: None,
        }
    }

    /// Add the coordinator's request-metrics source.
    pub fn with_server(mut self, server: ServerSource) -> LiveSources {
        self.server = Some(server);
        self
    }

    /// Add the `(inflight, capacity)` queue gauge source.
    pub fn with_queue(mut self, queue: QueueSource) -> LiveSources {
        self.queue = Some(queue);
        self
    }

    /// Add the per-model source (zoo deployments).
    pub fn with_models(mut self, models: ModelsSource) -> LiveSources {
        self.models = Some(models);
        self
    }

    /// Per-model snapshots, in registration order (empty when no
    /// model source is attached — a single-model process).
    pub fn models(&self) -> Vec<(String, ModelLiveStats)> {
        self.models.as_ref().map(|m| m()).unwrap_or_default()
    }

    /// The coordinator's request metrics, when a server source is
    /// attached.
    pub fn server_snapshot(&self) -> Option<MetricsSnapshot> {
        self.server.as_ref().map(|s| s())
    }

    /// Per-store snapshots, in shard order.
    pub fn stores(&self) -> Vec<(String, StoreMetrics)> {
        (self.stores)()
    }

    /// Merged per-layer cost estimates.
    pub fn costs(&self) -> Vec<(String, LayerCost)> {
        (self.costs)()
    }

    /// All stores folded into one [`StoreMetrics`] — what the stats
    /// socket's `Metrics` frame answers.
    pub fn merged_metrics(&self) -> StoreMetrics {
        let mut merged = StoreMetrics::default();
        for (_, m) in self.stores() {
            merged.merge(&m);
        }
        merged
    }

    /// The cost table as a [`CostProfile`] — what the stats socket's
    /// `CostProfile` frame answers (same JSON `f2f rebalance` eats).
    pub fn cost_profile(&self) -> CostProfile {
        let mut profile = CostProfile::new();
        for (name, cost) in self.costs() {
            profile.record(&name, cost);
        }
        profile
    }

    /// One watchdog observation: request p99 plus per-layer EWMAs.
    pub fn watchdog_sample(&self) -> WatchdogSample {
        let request_p99_ns = self
            .server
            .as_ref()
            .map(|s| s().p99.as_nanos() as f64)
            .unwrap_or(0.0);
        let layers = self
            .costs()
            .into_iter()
            .map(|(name, c)| {
                (
                    name,
                    c.decode_estimate().unwrap_or(0.0),
                    c.gemv_estimate().unwrap_or(0.0),
                )
            })
            .collect();
        WatchdogSample { request_p99_ns, layers }
    }

    /// The full live snapshot as self-describing JSON. Objects and
    /// numbers only (shards and layers are objects keyed by name, not
    /// arrays) so [`StatsSnapshot::parse_json`] reads it with the
    /// crate's hardened object-only JSON reader.
    pub fn stats_json(&self) -> String {
        let mut out = String::with_capacity(1024);
        out.push_str("{\"schema\": ");
        out.push_str(&STATS_SCHEMA.to_string());
        out.push_str(", \"ts_ns\": ");
        out.push_str(&super::unix_now_ns().to_string());
        out.push_str(", \"pid\": ");
        out.push_str(&std::process::id().to_string());
        if let Some(server) = self.server.as_ref() {
            let s = server();
            out.push_str(",\n \"server\": {");
            push_num(&mut out, "completed", s.completed as f64);
            out.push_str(", ");
            push_num(&mut out, "batches", s.batches as f64);
            out.push_str(", ");
            push_num(&mut out, "errors", s.errors as f64);
            out.push_str(", ");
            push_num(&mut out, "mean_batch_size", s.mean_batch_size());
            out.push_str(", ");
            push_num(&mut out, "request_p50_us", dur_us(s.p50));
            out.push_str(", ");
            push_num(&mut out, "request_p95_us", dur_us(s.p95));
            out.push_str(", ");
            push_num(&mut out, "request_p99_us", dur_us(s.p99));
            out.push_str(", ");
            push_num(&mut out, "request_max_us", dur_us(s.max));
            if let Some(queue) = self.queue.as_ref() {
                let (depth, capacity) = queue();
                out.push_str(", ");
                push_num(&mut out, "queue_depth", depth as f64);
                out.push_str(", ");
                push_num(&mut out, "queue_capacity", capacity as f64);
            }
            out.push('}');
        }
        out.push_str(",\n \"shards\": {");
        for (i, (name, m)) in self.stores().iter().enumerate() {
            if i > 0 {
                out.push_str(",\n   ");
            }
            out.push('"');
            events::escape_into(name, &mut out);
            out.push_str("\": {");
            let lookups = m.hits + m.misses;
            let hit_rate = if lookups == 0 {
                0.0
            } else {
                m.hits as f64 / lookups as f64
            };
            push_num(&mut out, "hits", m.hits as f64);
            out.push_str(", ");
            push_num(&mut out, "misses", m.misses as f64);
            out.push_str(", ");
            push_num(&mut out, "hit_rate", hit_rate);
            out.push_str(", ");
            push_num(&mut out, "decodes", m.decodes as f64);
            out.push_str(", ");
            push_num(&mut out, "evictions", m.evictions as f64);
            out.push_str(", ");
            push_num(&mut out, "prefetches", m.prefetches as f64);
            out.push_str(", ");
            push_num(
                &mut out,
                "readahead_skips",
                m.readahead_skips as f64,
            );
            out.push_str(", ");
            push_num(&mut out, "cached_bytes", m.cached_bytes as f64);
            out.push_str(", ");
            push_num(&mut out, "cached_layers", m.cached_layers as f64);
            out.push_str(", ");
            push_num(
                &mut out,
                "decode_samples",
                m.decode_hist.count() as f64,
            );
            out.push_str(", ");
            push_hist_us(&mut out, "decode", &m.decode_hist);
            out.push_str(", ");
            push_num(&mut out, "gemv_samples", m.gemv_hist.count() as f64);
            out.push_str(", ");
            push_hist_us(&mut out, "gemv", &m.gemv_hist);
            out.push('}');
        }
        out.push_str("},\n \"layers\": {");
        for (i, (name, c)) in self.costs().iter().enumerate() {
            if i > 0 {
                out.push_str(",\n   ");
            }
            out.push('"');
            events::escape_into(name, &mut out);
            out.push_str("\": {");
            push_num(&mut out, "decode_ns", c.decode_ns);
            out.push_str(", ");
            push_num(&mut out, "gemv_ns", c.gemv_ns);
            out.push_str(", ");
            push_num(&mut out, "decode_samples", c.decode_samples as f64);
            out.push_str(", ");
            push_num(&mut out, "gemv_samples", c.gemv_samples as f64);
            out.push('}');
        }
        out.push('}');
        if let Some(models) = self.models.as_ref() {
            out.push_str(",\n \"models\": {");
            for (i, (id, m)) in models().iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n   ");
                }
                out.push('"');
                events::escape_into(id, &mut out);
                out.push_str("\": {");
                push_num(&mut out, "completed", m.completed as f64);
                out.push_str(", ");
                push_num(&mut out, "errors", m.errors as f64);
                out.push_str(", ");
                push_num(&mut out, "request_p50_us", dur_us(m.p50));
                out.push_str(", ");
                push_num(&mut out, "request_p99_us", dur_us(m.p99));
                out.push_str(", ");
                push_num(
                    &mut out,
                    "mean_batch_size",
                    m.mean_batch_size,
                );
                out.push_str(", ");
                push_num(
                    &mut out,
                    "chain_layers",
                    m.chain_layers as f64,
                );
                out.push_str(", ");
                push_num(
                    &mut out,
                    "cached_layers",
                    m.cached_layers as f64,
                );
                out.push_str(", ");
                push_num(
                    &mut out,
                    "cached_bytes",
                    m.cached_bytes as f64,
                );
                out.push('}');
            }
            out.push('}');
        }
        let totals = events::totals();
        out.push_str(",\n \"events\": {");
        push_num(&mut out, "emitted", totals.emitted as f64);
        out.push_str(", ");
        push_num(&mut out, "dropped", totals.dropped as f64);
        out.push_str("}}\n");
        out
    }
}

fn dur_us(d: std::time::Duration) -> f64 {
    d.as_secs_f64() * 1e6
}

fn push_num(out: &mut String, key: &str, v: f64) {
    out.push('"');
    out.push_str(key);
    out.push_str("\": ");
    if v.is_finite() {
        out.push_str(&format!("{v:.3}"));
    } else {
        out.push('0');
    }
}

fn push_hist_us(out: &mut String, prefix: &str, h: &super::HdrLite) {
    for (label, q) in
        [("p50", 0.50), ("p95", 0.95), ("p99", 0.99)]
    {
        push_num(
            out,
            &format!("{prefix}_{label}_us"),
            dur_us(h.percentile(q)),
        );
        out.push_str(", ");
    }
    push_num(out, &format!("{prefix}_max_us"), dur_us(h.max()));
}

// ---------------------------------------------------------------------
// Client side: parse + render (what `f2f top` draws).
// ---------------------------------------------------------------------

/// Named numeric fields of one JSON object.
pub type Fields = Vec<(String, f64)>;

/// Look up one field; 0.0 when absent (forward compatibility — a
/// newer server may drop or rename fields the renderer tolerates).
pub fn field(fields: &[(String, f64)], key: &str) -> f64 {
    fields
        .iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| *v)
        .unwrap_or(0.0)
}

/// A parsed stats document, field order preserved.
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    /// Snapshot wall-clock time, ns since the unix epoch.
    pub ts_ns: u64,
    /// Pid of the serving process.
    pub pid: u64,
    /// Coordinator request metrics (empty when the document has none).
    pub server: Fields,
    /// Per-shard store metrics, keyed by store name.
    pub shards: Vec<(String, Fields)>,
    /// Per-layer cost estimates, keyed by layer name.
    pub layers: Vec<(String, Fields)>,
    /// Per-model request/cache stats, keyed by model id (empty for
    /// single-model processes).
    pub models: Vec<(String, Fields)>,
    /// Journal counters (`emitted`, `dropped`).
    pub events: Fields,
}

impl StatsSnapshot {
    /// Parse a [`LiveSources::stats_json`] document. Unknown keys and
    /// non-numeric leaves are ignored (forward compatibility); a
    /// document that is not an object-of-objects errors cleanly.
    pub fn parse_json(s: &str) -> Result<StatsSnapshot> {
        use crate::shard::rebalance::json::{parse, Value};
        let Value::Object(root) = parse(s)? else {
            bail!("stats document: top level is not a JSON object");
        };
        let mut snap = StatsSnapshot::default();
        for (key, value) in root {
            match (key.as_str(), value) {
                ("ts_ns", Value::Number(v)) => {
                    snap.ts_ns = num_u64(v);
                }
                ("pid", Value::Number(v)) => {
                    snap.pid = num_u64(v);
                }
                ("server", Value::Object(fields)) => {
                    snap.server = numeric_fields(fields);
                }
                ("events", Value::Object(fields)) => {
                    snap.events = numeric_fields(fields);
                }
                ("shards", Value::Object(groups)) => {
                    snap.shards = nested_fields(groups);
                }
                ("layers", Value::Object(groups)) => {
                    snap.layers = nested_fields(groups);
                }
                ("models", Value::Object(groups)) => {
                    snap.models = nested_fields(groups);
                }
                _ => {} // schema/title/unknown: ignore
            }
        }
        Ok(snap)
    }

    /// Render the refreshing `f2f top` view: a summary line, the
    /// per-shard table, and the per-layer cost table.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(1024);
        let ev_emitted = field(&self.events, "emitted");
        let ev_dropped = field(&self.events, "dropped");
        out.push_str(&format!(
            "f2f top — pid {} · {} shard(s) · events {:.0} emitted / \
             {:.0} dropped\n",
            self.pid,
            self.shards.len(),
            ev_emitted,
            ev_dropped,
        ));
        if !self.server.is_empty() {
            out.push_str(&format!(
                "requests: {:.0} done · {:.0} err · queue {:.0}/{:.0} \
                 · batch {:.1} · p50/p95/p99 {:.0}/{:.0}/{:.0} µs\n",
                field(&self.server, "completed"),
                field(&self.server, "errors"),
                field(&self.server, "queue_depth"),
                field(&self.server, "queue_capacity"),
                field(&self.server, "mean_batch_size"),
                field(&self.server, "request_p50_us"),
                field(&self.server, "request_p95_us"),
                field(&self.server, "request_p99_us"),
            ));
        }
        let mut shards = Table::new(
            "shards",
            &[
                "shard",
                "hit%",
                "decodes",
                "evict",
                "ra-skip",
                "cached KiB",
                "layers",
                "decode p50/p95/p99 µs",
                "gemv p50/p95/p99 µs",
            ],
        );
        for (name, f) in &self.shards {
            shards.row(vec![
                name.clone(),
                format!("{:.1}", field(f, "hit_rate") * 100.0),
                format!("{:.0}", field(f, "decodes")),
                format!("{:.0}", field(f, "evictions")),
                format!("{:.0}", field(f, "readahead_skips")),
                format!("{:.0}", field(f, "cached_bytes") / 1024.0),
                format!("{:.0}", field(f, "cached_layers")),
                format!(
                    "{:.0}/{:.0}/{:.0}",
                    field(f, "decode_p50_us"),
                    field(f, "decode_p95_us"),
                    field(f, "decode_p99_us"),
                ),
                format!(
                    "{:.0}/{:.0}/{:.0}",
                    field(f, "gemv_p50_us"),
                    field(f, "gemv_p95_us"),
                    field(f, "gemv_p99_us"),
                ),
            ]);
        }
        out.push_str(&shards.render());
        if !self.models.is_empty() {
            let mut models = Table::new(
                "models",
                &[
                    "model",
                    "done",
                    "err",
                    "batch",
                    "p50/p99 µs",
                    "chain",
                    "cached",
                    "cached KiB",
                ],
            );
            for (id, f) in &self.models {
                models.row(vec![
                    id.clone(),
                    format!("{:.0}", field(f, "completed")),
                    format!("{:.0}", field(f, "errors")),
                    format!("{:.1}", field(f, "mean_batch_size")),
                    format!(
                        "{:.0}/{:.0}",
                        field(f, "request_p50_us"),
                        field(f, "request_p99_us"),
                    ),
                    format!("{:.0}", field(f, "chain_layers")),
                    format!("{:.0}", field(f, "cached_layers")),
                    format!(
                        "{:.0}",
                        field(f, "cached_bytes") / 1024.0
                    ),
                ]);
            }
            out.push_str(&models.render());
        }
        let mut layers = Table::new(
            "layers",
            &["layer", "decode µs", "gemv µs/item", "samples d/g"],
        );
        const MAX_LAYER_ROWS: usize = 32;
        for (name, f) in self.layers.iter().take(MAX_LAYER_ROWS) {
            layers.row(vec![
                name.clone(),
                format!("{:.1}", field(f, "decode_ns") / 1e3),
                format!("{:.1}", field(f, "gemv_ns") / 1e3),
                format!(
                    "{:.0}/{:.0}",
                    field(f, "decode_samples"),
                    field(f, "gemv_samples"),
                ),
            ]);
        }
        out.push_str(&layers.render());
        if self.layers.len() > MAX_LAYER_ROWS {
            out.push_str(&format!(
                "… and {} more layers\n",
                self.layers.len() - MAX_LAYER_ROWS
            ));
        }
        out
    }
}

fn num_u64(v: f64) -> u64 {
    if v.is_finite() && v >= 0.0 {
        v as u64
    } else {
        0
    }
}

fn numeric_fields(
    fields: Vec<(String, crate::shard::rebalance::json::Value)>,
) -> Fields {
    use crate::shard::rebalance::json::Value;
    fields
        .into_iter()
        .filter_map(|(k, v)| match v {
            Value::Number(x) => Some((k, x)),
            _ => None,
        })
        .collect()
}

fn nested_fields(
    groups: Vec<(String, crate::shard::rebalance::json::Value)>,
) -> Vec<(String, Fields)> {
    use crate::shard::rebalance::json::Value;
    groups
        .into_iter()
        .filter_map(|(name, v)| match v {
            Value::Object(fields) => {
                Some((name, numeric_fields(fields)))
            }
            _ => None,
        })
        .collect()
}

// ---------------------------------------------------------------------
// Server + poll client (unix: rides the IPC wire protocol).
// ---------------------------------------------------------------------

#[cfg(unix)]
pub use unix_impl::{poll_events, poll_stats, StatsServer};

#[cfg(unix)]
mod unix_impl {
    use super::*;
    use crate::ipc::wire::{self, Request, Response, WireError};
    use std::os::unix::net::{UnixListener, UnixStream};
    use std::path::{Path, PathBuf};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;

    const POLL: Duration = Duration::from_millis(5);

    /// A stats socket over [`LiveSources`]: accepts connections on a
    /// dedicated unix socket and answers wire requests from live
    /// snapshots while the serving process keeps taking traffic.
    /// Dropping (or [`stop`](StatsServer::stop)ping) it closes the
    /// socket and removes the socket file.
    pub struct StatsServer {
        shutdown: Arc<AtomicBool>,
        accept: Option<std::thread::JoinHandle<()>>,
        socket_path: PathBuf,
    }

    impl StatsServer {
        /// Bind `socket_path` (replacing a stale socket file) and
        /// serve `sources` from a background thread.
        pub fn start(
            socket_path: &Path,
            sources: LiveSources,
        ) -> Result<StatsServer> {
            if socket_path.exists() {
                let _ = std::fs::remove_file(socket_path);
            }
            let listener =
                UnixListener::bind(socket_path).with_context(|| {
                    format!("bind stats socket {}", socket_path.display())
                })?;
            listener.set_nonblocking(true).context(
                "set stats listener nonblocking",
            )?;
            let shutdown = Arc::new(AtomicBool::new(false));
            let accept = {
                let shutdown = Arc::clone(&shutdown);
                std::thread::Builder::new()
                    .name("f2f-stats".into())
                    .spawn(move || {
                        accept_loop(&listener, &sources, &shutdown)
                    })
                    .context("spawn stats accept thread")?
            };
            Ok(StatsServer {
                shutdown,
                accept: Some(accept),
                socket_path: socket_path.to_path_buf(),
            })
        }

        /// The socket path this server listens on.
        pub fn socket_path(&self) -> &Path {
            &self.socket_path
        }

        /// Close the socket and join the serving threads.
        pub fn stop(mut self) {
            self.halt();
        }

        fn halt(&mut self) {
            self.shutdown.store(true, Ordering::Release);
            if let Some(t) = self.accept.take() {
                let _ = t.join();
            }
            let _ = std::fs::remove_file(&self.socket_path);
        }
    }

    impl Drop for StatsServer {
        fn drop(&mut self) {
            self.halt();
        }
    }

    fn accept_loop(
        listener: &UnixListener,
        sources: &LiveSources,
        shutdown: &Arc<AtomicBool>,
    ) {
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !shutdown.load(Ordering::Acquire) {
            conns.retain(|h| !h.is_finished());
            match listener.accept() {
                Ok((stream, _)) => {
                    let sources = sources.clone();
                    let shutdown = Arc::clone(shutdown);
                    let spawned = std::thread::Builder::new()
                        .name("f2f-stats-conn".into())
                        .spawn(move || {
                            serve_conn(stream, &sources, &shutdown)
                        });
                    match spawned {
                        Ok(h) => conns.push(h),
                        Err(_) => std::thread::sleep(POLL),
                    }
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    std::thread::sleep(POLL);
                }
                Err(_) => std::thread::sleep(POLL),
            }
        }
        for h in conns {
            let _ = h.join();
        }
    }

    fn serve_conn(
        stream: UnixStream,
        sources: &LiveSources,
        shutdown: &Arc<AtomicBool>,
    ) {
        let mut stream = stream;
        let _ = stream.set_nonblocking(false);
        let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
        loop {
            if shutdown.load(Ordering::Acquire) {
                return;
            }
            let req = match wire::read_request(&mut stream) {
                Ok(req) => req,
                Err(WireError::TimedOut) => continue,
                Err(WireError::Eof) | Err(WireError::Io(_)) => return,
                Err(WireError::Corrupt(msg)) => {
                    let _ = wire::send_response(
                        &mut stream,
                        &Response::Err {
                            message: format!("corrupt frame: {msg}"),
                        },
                    );
                    return;
                }
            };
            let (resp, stop) = answer(sources, req, shutdown);
            if wire::send_response(&mut stream, &resp).is_err() {
                return;
            }
            if stop {
                return;
            }
        }
    }

    fn answer(
        sources: &LiveSources,
        req: Request,
        shutdown: &Arc<AtomicBool>,
    ) -> (Response, bool) {
        match req {
            Request::Metrics => {
                (Response::Metrics(sources.merged_metrics()), false)
            }
            Request::CostProfile => (
                Response::CostProfile {
                    json: sources.cost_profile().to_json(),
                },
                false,
            ),
            Request::TraceDump => (
                Response::Trace {
                    pid: std::process::id(),
                    events: crate::obs::snapshot(),
                },
                false,
            ),
            Request::Stats => {
                (Response::Stats { json: sources.stats_json() }, false)
            }
            Request::Events { max } => {
                let max = max.min(MAX_EVENT_LINES) as usize;
                (
                    Response::Events {
                        jsonl: events::recent(max).join("\n"),
                    },
                    false,
                )
            }
            Request::Fetch { .. } | Request::Prefetch { .. } => (
                Response::Err {
                    message: "stats socket serves no layers".into(),
                },
                false,
            ),
            Request::Shutdown => {
                shutdown.store(true, Ordering::Release);
                (Response::Bye, true)
            }
        }
    }

    fn call(
        socket: &Path,
        req: &Request,
        timeout: Duration,
    ) -> Result<Response> {
        let mut stream =
            UnixStream::connect(socket).with_context(|| {
                format!("connect stats socket {}", socket.display())
            })?;
        let timeout = timeout.max(Duration::from_millis(10));
        let _ = stream.set_read_timeout(Some(timeout));
        let _ = stream.set_write_timeout(Some(timeout));
        wire::send_request(&mut stream, req)
            .context("send stats request")?;
        match wire::read_response(&mut stream) {
            Ok(Response::Err { message }) => {
                bail!("stats peer error: {message}")
            }
            Ok(resp) => Ok(resp),
            Err(e) => bail!("read stats response: {e}"),
        }
    }

    /// One live-stats poll: the raw JSON document the peer serves.
    pub fn poll_stats(socket: &Path, timeout: Duration) -> Result<String> {
        match call(socket, &Request::Stats, timeout)? {
            Response::Stats { json } => Ok(json),
            other => bail!("expected a stats frame, got {other:?}"),
        }
    }

    /// One journal poll: the newest `max` lines as JSONL.
    pub fn poll_events(
        socket: &Path,
        max: u32,
        timeout: Duration,
    ) -> Result<String> {
        match call(socket, &Request::Events { max }, timeout)? {
            Response::Events { jsonl } => Ok(jsonl),
            other => bail!("expected an events frame, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::HdrLite;
    use std::time::Duration;

    fn fake_sources() -> LiveSources {
        let stores: StoresSource = Arc::new(|| {
            let mut decode_hist = HdrLite::new();
            decode_hist.record(Duration::from_micros(120));
            decode_hist.record(Duration::from_micros(480));
            let mut gemv_hist = HdrLite::new();
            gemv_hist.record(Duration::from_micros(40));
            vec![(
                "worker 0".to_string(),
                StoreMetrics {
                    hits: 30,
                    misses: 10,
                    decodes: 10,
                    evictions: 2,
                    readahead_skips: 1,
                    cached_bytes: 4096,
                    cached_layers: 3,
                    decode_hist,
                    gemv_hist,
                    ..StoreMetrics::default()
                },
            )]
        });
        let costs: CostsSource = Arc::new(|| {
            vec![(
                "mlp/fc0".to_string(),
                LayerCost {
                    decode_ns: 120_000.0,
                    gemv_ns: 40_000.0,
                    decode_samples: 10,
                    gemv_samples: 40,
                },
            )]
        });
        let server: ServerSource = Arc::new(|| {
            let m = crate::coordinator::Metrics::default();
            m.record_batch(
                &[Duration::from_micros(500), Duration::from_micros(900)],
                Duration::from_micros(700),
            );
            m.snapshot()
        });
        let queue: QueueSource = Arc::new(|| (3, 4096));
        LiveSources::new(stores, costs)
            .with_server(server)
            .with_queue(queue)
    }

    #[test]
    fn stats_json_round_trips_through_the_hardened_parser() {
        let sources = fake_sources();
        let json = sources.stats_json();
        let snap = StatsSnapshot::parse_json(&json).unwrap();
        assert_eq!(snap.pid, u64::from(std::process::id()));
        assert!(snap.ts_ns > 0);
        assert_eq!(snap.shards.len(), 1);
        let (name, f) = &snap.shards[0];
        assert_eq!(name, "worker 0");
        assert_eq!(field(f, "hits"), 30.0);
        assert!((field(f, "hit_rate") - 0.75).abs() < 1e-9);
        assert_eq!(field(f, "decode_samples"), 2.0);
        assert!(field(f, "decode_p99_us") > 0.0);
        assert_eq!(snap.layers.len(), 1);
        let (lname, lf) = &snap.layers[0];
        assert_eq!(lname, "mlp/fc0");
        assert_eq!(field(lf, "decode_ns"), 120_000.0);
        assert_eq!(field(&snap.server, "completed"), 2.0);
        assert_eq!(field(&snap.server, "queue_capacity"), 4096.0);
        assert!(field(&snap.server, "request_p99_us") > 0.0);
    }

    #[test]
    fn render_shows_every_section() {
        let sources = fake_sources();
        let snap =
            StatsSnapshot::parse_json(&sources.stats_json()).unwrap();
        let view = snap.render();
        assert!(view.contains("f2f top"), "{view}");
        assert!(view.contains("requests:"), "{view}");
        assert!(view.contains("worker 0"), "{view}");
        assert!(view.contains("mlp/fc0"), "{view}");
        assert!(view.contains("hit%"), "{view}");
    }

    #[test]
    fn models_section_round_trips_and_renders() {
        let models: ModelsSource = Arc::new(|| {
            vec![
                (
                    "chat".to_string(),
                    ModelLiveStats {
                        completed: 12,
                        errors: 1,
                        p50: Duration::from_micros(400),
                        p99: Duration::from_micros(950),
                        mean_batch_size: 2.5,
                        chain_layers: 6,
                        cached_layers: 4,
                        cached_bytes: 8192,
                    },
                ),
                ("rank".to_string(), ModelLiveStats::default()),
            ]
        });
        let sources = fake_sources().with_models(models);
        assert_eq!(sources.models().len(), 2);
        let snap =
            StatsSnapshot::parse_json(&sources.stats_json()).unwrap();
        assert_eq!(snap.models.len(), 2);
        let (id, f) = &snap.models[0];
        assert_eq!(id, "chat");
        assert_eq!(field(f, "completed"), 12.0);
        assert_eq!(field(f, "errors"), 1.0);
        assert_eq!(field(f, "request_p50_us"), 400.0);
        assert_eq!(field(f, "request_p99_us"), 950.0);
        assert_eq!(field(f, "mean_batch_size"), 2.5);
        assert_eq!(field(f, "chain_layers"), 6.0);
        assert_eq!(field(f, "cached_bytes"), 8192.0);
        let view = snap.render();
        assert!(view.contains("models"), "{view}");
        assert!(view.contains("chat"), "{view}");
        assert!(view.contains("rank"), "{view}");

        // Without a model source the section is absent and the view
        // unchanged — single-model processes emit byte-identical JSON.
        let solo =
            StatsSnapshot::parse_json(&fake_sources().stats_json())
                .unwrap();
        assert!(solo.models.is_empty());
        assert!(!solo.render().contains("models"));
    }

    #[test]
    fn merged_metrics_fold_across_stores() {
        let stores: StoresSource = Arc::new(|| {
            let a = StoreMetrics { hits: 5, ..StoreMetrics::default() };
            let b = StoreMetrics {
                hits: 7,
                misses: 2,
                ..StoreMetrics::default()
            };
            vec![("s0".into(), a), ("s1".into(), b)]
        });
        let costs: CostsSource = Arc::new(Vec::new);
        let sources = LiveSources::new(stores, costs);
        let merged = sources.merged_metrics();
        assert_eq!(merged.hits, 12);
        assert_eq!(merged.misses, 2);
        assert!(sources.cost_profile().is_empty());
    }

    #[test]
    fn watchdog_sample_reflects_costs_and_p99() {
        let sample = fake_sources().watchdog_sample();
        assert!(sample.request_p99_ns > 0.0);
        assert_eq!(sample.layers.len(), 1);
        assert_eq!(sample.layers[0].1, 120_000.0);
        assert_eq!(sample.layers[0].2, 40_000.0);
    }

    #[test]
    fn malformed_stats_documents_error_cleanly() {
        assert!(StatsSnapshot::parse_json("").is_err());
        assert!(StatsSnapshot::parse_json("42").is_err());
        assert!(StatsSnapshot::parse_json("{\"shards\": [}").is_err());
        // Unknown keys and non-numeric leaves are tolerated.
        let snap = StatsSnapshot::parse_json(
            "{\"future\": \"stuff\", \"pid\": 9, \
             \"shards\": {\"s\": {\"hits\": 1, \"note\": \"x\"}}}",
        )
        .unwrap();
        assert_eq!(snap.pid, 9);
        assert_eq!(field(&snap.shards[0].1, "hits"), 1.0);
    }

    #[cfg(unix)]
    #[test]
    fn stats_server_answers_every_frame_live() {
        use crate::ipc::wire::{self, Request, Response};
        use std::os::unix::net::UnixStream;

        let dir = std::env::temp_dir();
        let socket = dir.join(format!(
            "f2f-stats-test-{}.sock",
            std::process::id()
        ));
        let server =
            StatsServer::start(&socket, fake_sources()).unwrap();
        crate::obs::events::set_stderr_mirror(false);
        crate::obs::events::warn("stats_unit_probe", "probe", &[]);

        let json =
            poll_stats(&socket, Duration::from_secs(5)).unwrap();
        let snap = StatsSnapshot::parse_json(&json).unwrap();
        assert_eq!(snap.shards.len(), 1);

        let jsonl =
            poll_events(&socket, 4096, Duration::from_secs(5)).unwrap();
        assert!(
            jsonl.contains("stats_unit_probe"),
            "journal tail served: {jsonl}"
        );

        let mut stream = UnixStream::connect(&socket).unwrap();
        let t = Some(Duration::from_secs(5));
        stream.set_read_timeout(t).unwrap();
        wire::send_request(&mut stream, &Request::Metrics).unwrap();
        let Response::Metrics(m) =
            wire::read_response(&mut stream).unwrap()
        else {
            panic!("not a metrics frame");
        };
        assert_eq!(m.hits, 30);
        wire::send_request(&mut stream, &Request::CostProfile).unwrap();
        let Response::CostProfile { json } =
            wire::read_response(&mut stream).unwrap()
        else {
            panic!("not a costs frame");
        };
        let profile =
            crate::shard::CostProfile::parse_json(&json).unwrap();
        assert!(profile.get("mlp/fc0").is_some());
        wire::send_request(&mut stream, &Request::TraceDump).unwrap();
        let Response::Trace { pid, .. } =
            wire::read_response(&mut stream).unwrap()
        else {
            panic!("not a trace frame");
        };
        assert_eq!(pid, std::process::id());
        // A layer fetch is politely refused, connection stays usable.
        wire::send_request(
            &mut stream,
            &Request::Fetch {
                layer: "x".into(),
                model: String::new(),
                trace: 0,
            },
        )
        .unwrap();
        assert!(matches!(
            wire::read_response(&mut stream).unwrap(),
            Response::Err { .. }
        ));
        wire::send_request(&mut stream, &Request::Stats).unwrap();
        assert!(matches!(
            wire::read_response(&mut stream).unwrap(),
            Response::Stats { .. }
        ));

        server.stop();
        assert!(!socket.exists(), "stop removes the socket file");
    }
}

//! Compress a (synthetic) sparse ResNet-50 in signed INT8 — the paper's
//! Table 2 ResNet-50/INT8 workload at laptop scale, across both pruning
//! rates.
//!
//! ```text
//! cargo run --release --example compress_resnet50 [weights_per_layer]
//! cargo run --release --example compress_resnet50 -- --serve
//! ```
//!
//! `--serve` switches to the zoo demo leg: compress the canonical
//! ResNet ladder *with kind records* (a v3 container carrying its
//! conv-as-GEMM chain, downsample residuals included) next to a
//! companion Transformer, serve both tenants from one shared-budget
//! registry, and print per-model observed cost tables.

use f2f::container::Dtype;
use f2f::coordinator::Backend;
use f2f::models::{
    resnet50_layers, resnet_chain, tiny_resnet_layers,
    tiny_transformer_layers, transformer_chain, LayerSpec,
    SyntheticLayer, WeightGen,
};
use f2f::pipeline::{CompressionConfig, Compressor, LayerReport};
use f2f::pruning::PruneMethod;
use f2f::registry::{ModelRegistry, ZooModel};
use f2f::report::Table;
use f2f::store::{ReadaheadPolicy, StoreConfig};

fn main() {
    if std::env::args().any(|a| a == "--serve") {
        serve_zoo_demo();
        return;
    }
    let max_w: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096);

    let picks = [
        "conv1",
        "group2_layer3_conv1",
        "group3_layer3_conv2",
        "group4_layer0_downsample",
        "fc",
    ];
    let all = resnet50_layers();
    let layers: Vec<SyntheticLayer> = picks
        .iter()
        .map(|n| {
            let spec = all.iter().find(|l| &l.name == n).unwrap();
            SyntheticLayer::generate(spec, WeightGen::default(), 0x52)
                .truncated(max_w)
        })
        .collect();

    let mut table = Table::new(
        "ResNet-50 signed INT8 (synthetic), magnitude pruning",
        &["S", "N_s", "E%", "mem_red%", "time"],
    );
    for &s in &[0.7, 0.9] {
        for n_s in [0usize, 1, 2] {
            let cfg = CompressionConfig {
                sparsity: s,
                n_s,
                method: PruneMethod::Magnitude,
                beam: if n_s >= 2 { Some(8) } else { None },
                ..Default::default()
            };
            let t0 = std::time::Instant::now();
            let (_, reports) =
                Compressor::new(cfg).compress_model(&layers, Dtype::I8);
            let agg = LayerReport::aggregate("resnet50", &reports);
            table.row(vec![
                format!("{s:.1}"),
                n_s.to_string(),
                format!("{:.2}", agg.efficiency),
                format!("{:.2}", agg.memory_reduction),
                format!("{:?}", t0.elapsed()),
            ]);
        }
    }
    print!("{}", table.render());
    println!(
        "expected shape (Table 2): E and memory reduction rise with N_s;\n\
         memory reduction approaches S as E -> 100%."
    );
}

/// Compress one tenant's layer table with its chain into a v3
/// container and load it back as a zoo tenant.
fn compress_tenant(
    id: &str,
    specs: &[LayerSpec],
    chain: f2f::container::ChainSpec,
    cfg: CompressionConfig,
) -> ZooModel {
    let layers: Vec<SyntheticLayer> = specs
        .iter()
        .map(|s| SyntheticLayer::generate(s, WeightGen::default(), 0x52))
        .collect();
    let (container, reports) =
        Compressor::new(cfg).compress_model(&layers, Dtype::I8);
    let agg = LayerReport::aggregate(id, &reports);
    println!(
        "{id}: {} layers compressed, E={:.2}% mem_reduction={:.2}%",
        specs.len(),
        agg.efficiency,
        agg.memory_reduction
    );
    let bytes = f2f::container::write_container_v3(&container, &[chain]);
    ZooModel::from_bytes(id, &bytes).expect("v3 container round-trip")
}

/// The `--serve` demo: a ResNet ladder (conv-as-GEMM kind records,
/// downsample residuals) and a companion Transformer served
/// concurrently from one registry under a shared byte budget small
/// enough that a burst on one tenant evicts the other's cold layers.
fn serve_zoo_demo() {
    let cfg = CompressionConfig {
        sparsity: 0.9,
        n_s: 1,
        method: PruneMethod::Magnitude,
        beam: Some(8),
        ..Default::default()
    };
    let rn_specs = tiny_resnet_layers(&[(8, 32), (16, 64), (32, 128)]);
    let rn_chain = resnet_chain("resnet50", &rn_specs).expect("chain");
    let tx_specs = tiny_transformer_layers(1, 64, 128);
    let tx_chain =
        transformer_chain("transformer", &tx_specs).expect("chain");
    let decoded_bytes: usize = rn_specs
        .iter()
        .chain(&tx_specs)
        .map(|s| s.n_weights() * 4)
        .sum();

    let zoo = vec![
        compress_tenant("resnet50", &rn_specs, rn_chain, cfg),
        compress_tenant("transformer", &tx_specs, tx_chain, cfg),
    ];

    let budget = decoded_bytes * 6 / 10;
    let mut registry = ModelRegistry::new(
        &zoo,
        StoreConfig {
            cache_budget_bytes: budget,
            ..Default::default()
        },
    )
    .expect("registry")
    .with_readahead(ReadaheadPolicy::layers(1));
    println!(
        "zoo: {} models, combined decoded ~{} KiB, shared budget {} KiB",
        registry.n_models(),
        decoded_bytes >> 10,
        budget >> 10
    );

    for round in 0..3usize {
        for id in ["resnet50", "transformer"] {
            let dim = registry.chain(id).expect("chain").input_dim();
            let xs: Vec<Vec<f32>> = (0..4usize)
                .map(|i| {
                    (0..dim)
                        .map(|j| {
                            (((i * dim + j + round) as f32) * 0.53).cos()
                        })
                        .collect()
                })
                .collect();
            let ys = registry
                .forward_model_batch(id, &xs)
                .expect("zoo forward");
            assert!(
                ys.iter().flatten().all(|v| v.is_finite()),
                "{id}: non-finite output"
            );
        }
    }
    registry.wait_for_idle();

    if let Some(m) = registry.store_metrics() {
        println!(
            "shared store: decodes={} hits={} evictions={} \
             redundant_decodes={}",
            m.decodes, m.hits, m.evictions, m.redundant_decodes
        );
    }
    for id in registry.model_ids() {
        if let Some((layers, bytes)) = registry.model_cache(&id) {
            println!(
                "{id}: {layers} layers / {bytes} B resident after the \
                 interleaved burst"
            );
        }
        let mut table = Table::new(
            &format!("{id}: per-layer observed costs"),
            &["layer", "gemv_us_per_item", "samples"],
        );
        for (name, c) in registry.model_costs(&id) {
            table.row(vec![
                name,
                format!("{:.2}", c.gemv_ns / 1e3),
                c.gemv_samples.to_string(),
            ]);
        }
        print!("{}", table.render());
    }
}

//! Compress a (synthetic) sparse ResNet-50 in signed INT8 — the paper's
//! Table 2 ResNet-50/INT8 workload at laptop scale, across both pruning
//! rates.
//!
//! ```text
//! cargo run --release --example compress_resnet50 [weights_per_layer]
//! ```

use f2f::container::Dtype;
use f2f::models::{resnet50_layers, SyntheticLayer, WeightGen};
use f2f::pipeline::{CompressionConfig, Compressor, LayerReport};
use f2f::pruning::PruneMethod;
use f2f::report::Table;

fn main() {
    let max_w: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096);

    let picks = [
        "conv1",
        "group2_layer3_conv1",
        "group3_layer3_conv2",
        "group4_layer0_downsample",
        "fc",
    ];
    let all = resnet50_layers();
    let layers: Vec<SyntheticLayer> = picks
        .iter()
        .map(|n| {
            let spec = all.iter().find(|l| &l.name == n).unwrap();
            SyntheticLayer::generate(spec, WeightGen::default(), 0x52)
                .truncated(max_w)
        })
        .collect();

    let mut table = Table::new(
        "ResNet-50 signed INT8 (synthetic), magnitude pruning",
        &["S", "N_s", "E%", "mem_red%", "time"],
    );
    for &s in &[0.7, 0.9] {
        for n_s in [0usize, 1, 2] {
            let cfg = CompressionConfig {
                sparsity: s,
                n_s,
                method: PruneMethod::Magnitude,
                beam: if n_s >= 2 { Some(8) } else { None },
                ..Default::default()
            };
            let t0 = std::time::Instant::now();
            let (_, reports) =
                Compressor::new(cfg).compress_model(&layers, Dtype::I8);
            let agg = LayerReport::aggregate("resnet50", &reports);
            table.row(vec![
                format!("{s:.1}"),
                n_s.to_string(),
                format!("{:.2}", agg.efficiency),
                format!("{:.2}", agg.memory_reduction),
                format!("{:?}", t0.elapsed()),
            ]);
        }
    }
    print!("{}", table.render());
    println!(
        "expected shape (Table 2): E and memory reduction rise with N_s;\n\
         memory reduction approaches S as E -> 100%."
    );
}

//! Design-space sweep: memory reduction and encoder cost vs sparsity and
//! N_s — the ablation behind Table 1's "sequential principles are
//! crucial" claim, plus the hardware cost at each point (Appendix G).
//!
//! ```text
//! cargo run --release --example sweep_sparsity [bits]
//! ```

use f2f::correction::{compressed_bits_eq7, memory_save_eq2, DEFAULT_P};
use f2f::decoder::{DecoderSpec, SequentialDecoder};
use f2f::encoder::{Encoder, SlicedPlane, ViterbiEncoder};
use f2f::gf2::BitVecF2;
use f2f::report::Table;
use f2f::rng::Rng;

fn main() {
    let bits: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(50_000);
    let mut rng = Rng::new(7);

    let mut table = Table::new(
        &format!("sparsity sweep, N_in=8, {bits} random bits"),
        &[
            "S", "N_s", "N_out", "E%", "mem_red% (measured)",
            "mem_red% (Eq.2)", "xor_gates", "encode_time",
        ],
    );
    for &s in &[0.5, 0.6, 0.7, 0.8, 0.9, 0.95] {
        let data = BitVecF2::random(bits, 0.5, &mut rng);
        let mask = BitVecF2::random(bits, 1.0 - s, &mut rng);
        for n_s in [0usize, 1, 2] {
            let spec = DecoderSpec::for_sparsity(8, s, n_s);
            let dec = SequentialDecoder::random(spec, 0x5EED);
            let hw = dec.hardware_cost();
            let enc = if n_s >= 2 {
                ViterbiEncoder::with_beam(dec, 8)
            } else {
                ViterbiEncoder::new(dec)
            };
            let plane = SlicedPlane::new(&data, &mask, spec.n_out);
            let t0 = std::time::Instant::now();
            let res = enc.encode(&plane);
            let dt = t0.elapsed();
            let comp = compressed_bits_eq7(
                bits,
                8,
                spec.n_out,
                DEFAULT_P,
                res.stats.error_bits,
            );
            let measured = (1.0 - comp as f64 / bits as f64) * 100.0;
            let eq2 = memory_save_eq2(
                s,
                res.efficiency() / 100.0,
                10.0,
            ) * 100.0;
            table.row(vec![
                format!("{s:.2}"),
                n_s.to_string(),
                spec.n_out.to_string(),
                format!("{:.2}", res.efficiency()),
                format!("{measured:.2}"),
                format!("{eq2:.2}"),
                hw.xor_gates.to_string(),
                format!("{dt:.2?}"),
            ]);
        }
    }
    print!("{}", table.render());
    println!(
        "\nreading guide: measured memory reduction should approach S·100\n\
         as N_s grows (Table 1); Eq.2 is the closed-form with N_c = 10."
    );
}

//! END-TO-END DRIVER: all three layers composed.
//!
//! 1. (build time, `make artifacts`) Python lowers the JAX decode+matvec
//!    model — whose hot spot is the Pallas GF(2) kernel — to HLO text,
//!    one executable per batch size.
//! 2. This binary compresses a real 256×512 signed-INT8 layer with the
//!    paper's sequential fixed-to-fixed scheme (Rust encoder).
//! 3. The compressed streams are marshalled into the PJRT executables'
//!    input layout; the serving coordinator batches incoming requests
//!    and routes each batch to the right executable (1/8/32, padded).
//! 4. Outputs are cross-checked against the native Rust decode path
//!    (bit-exact weights ⇒ identical mat-vec up to f32 accumulation
//!    order), then a load test reports throughput + latency percentiles.
//!
//! ```text
//! make artifacts && cargo run --release --example serve_compressed
//! ```

use anyhow::{bail, Context, Result};
use f2f::container::CompressedLayer;
use f2f::coordinator::{Backend, InferenceServer, ServerConfig};
use f2f::decoder::SequentialDecoder;
use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
use f2f::pipeline::{CompressionConfig, Compressor};
use f2f::pruning::PruneMethod;
use f2f::runtime::{Input, LoadedModel, Runtime};
use f2f::sparse::DecodedLayer;
use std::path::PathBuf;

const ROWS: usize = 256;
const COLS: usize = 512;
const N_S: usize = 2;
const N_OUT: usize = 80;

fn artifacts_dir() -> PathBuf {
    std::env::var("F2F_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Marshalled PJRT inputs shared by every request (the weights).
struct StaticInputs {
    encoded_bits: Vec<f32>, // [8, l+N_s, 8]
    m_t: Vec<f32>,          // [K, N_out]
    corr: Vec<f32>,         // [8, l*N_out]
    invert: Vec<f32>,       // [8]
    mask: Vec<f32>,         // [n]
    scale: f32,
    l: usize,
}

fn marshal(layer: &CompressedLayer) -> StaticInputs {
    let n = layer.n_weights();
    let spec = layer.spec;
    let l = spec.num_blocks(n);
    let k = spec.total_inputs();
    let stream = l + spec.n_s;

    let mut encoded_bits = vec![0f32; 8 * stream * spec.n_in];
    let mut corr = vec![0f32; 8 * l * spec.n_out];
    let mut invert = vec![0f32; 8];
    for (p, plane) in layer.planes.iter().enumerate() {
        assert_eq!(plane.encoded.len(), stream);
        for (t, &chunk) in plane.encoded.iter().enumerate() {
            for b in 0..spec.n_in {
                encoded_bits[(p * stream + t) * spec.n_in + b] =
                    ((chunk >> b) & 1) as f32;
            }
        }
        for pos in plane.correction.positions() {
            corr[p * l * spec.n_out + pos] = 1.0;
        }
        invert[p] = plane.inverted as u8 as f32;
    }
    // m_t[j][i] = M[i][j] (transpose of the row-major decoder matrix).
    let dec = SequentialDecoder::random(spec, layer.m_seed);
    let mut m_t = vec![0f32; k * spec.n_out];
    for j in 0..k {
        for i in 0..spec.n_out {
            if dec.matrix().get(i, j) {
                m_t[j * spec.n_out + i] = 1.0;
            }
        }
    }
    let mask: Vec<f32> =
        (0..n).map(|i| layer.mask.get(i) as u8 as f32).collect();
    StaticInputs {
        encoded_bits,
        m_t,
        corr,
        invert,
        mask,
        scale: layer.scale,
        l,
    }
}

/// PJRT backend: one executable per batch size; requests are padded to
/// the smallest size that fits.
struct PjrtBackend {
    models: Vec<(usize, LoadedModel)>, // ascending batch sizes
    inputs: StaticInputs,
    #[allow(dead_code)]
    spec: f2f::decoder::DecoderSpec,
}

impl PjrtBackend {
    fn load(layer: &CompressedLayer) -> Result<Self> {
        let rt = Runtime::cpu()?;
        println!("PJRT platform: {}", rt.platform());
        let dir = artifacts_dir();
        let mut models = Vec::new();
        for b in [1usize, 8, 32] {
            let path = dir.join(format!("decode_matvec_b{b}.hlo.txt"));
            if !path.exists() {
                bail!(
                    "artifact {} missing — run `make artifacts` first",
                    path.display()
                );
            }
            models.push((b, rt.load_hlo_text(&path)?));
        }
        Ok(PjrtBackend {
            models,
            inputs: marshal(layer),
            spec: layer.spec,
        })
    }

    fn run_padded(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let (cap, model) = self
            .models
            .iter()
            .find(|(b, _)| *b >= xs.len())
            .or_else(|| self.models.last())
            .map(|(b, m)| (*b, m))
            .context("no executable")?;
        // Chunk if the batch exceeds the largest executable.
        if xs.len() > cap {
            let mut out = Vec::with_capacity(xs.len());
            for chunk in xs.chunks(cap) {
                out.extend(self.run_padded(chunk)?);
            }
            return Ok(out);
        }
        let mut xbuf = vec![0f32; cap * COLS];
        for (i, x) in xs.iter().enumerate() {
            xbuf[i * COLS..(i + 1) * COLS].copy_from_slice(x);
        }
        let si = &self.inputs;
        let stream = (si.l + N_S) as i64;
        let outputs = model.run(&[
            Input::F32(&si.encoded_bits, &[8, stream, 8]),
            Input::F32(&si.m_t, &[((N_S + 1) * 8) as i64, N_OUT as i64]),
            Input::F32(&si.corr, &[8, (si.l * N_OUT) as i64]),
            Input::F32(&si.invert, &[8]),
            Input::F32(&si.mask, &[(ROWS * COLS) as i64]),
            Input::F32(&xbuf, &[cap as i64, COLS as i64]),
            Input::F32(std::slice::from_ref(&si.scale), &[]),
        ])?;
        let y = &outputs[0];
        Ok(xs
            .iter()
            .enumerate()
            .map(|(i, _)| y[i * ROWS..(i + 1) * ROWS].to_vec())
            .collect())
    }
}

impl Backend for PjrtBackend {
    fn forward_batch(&mut self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.run_padded(xs).expect("PJRT execution failed")
    }
    fn input_dim(&self) -> usize {
        COLS
    }
    fn output_dim(&self) -> usize {
        ROWS
    }
}

fn main() -> Result<()> {
    // --- compress a layer (the paper's flagship config) ---
    let spec = LayerSpec { name: "serve/fc".into(), rows: ROWS, cols: COLS };
    let layer = SyntheticLayer::generate(&spec, WeightGen::default(), 0x5E);
    let (q, scale) = quantize_i8(&layer.weights);
    let compressor = Compressor::new(CompressionConfig {
        sparsity: 0.9,
        n_s: N_S,
        method: PruneMethod::Magnitude,
        beam: Some(8),
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let (compressed, report) =
        compressor.compress_i8("serve/fc", ROWS, COLS, &q, scale);
    println!(
        "compressed {}x{} INT8 layer in {:?}: E={:.2}% mem_reduction={:.2}%",
        ROWS, COLS, t0.elapsed(), report.efficiency, report.memory_reduction
    );

    // --- correctness: PJRT output == native Rust decode ---
    let pjrt = PjrtBackend::load(&compressed)?;
    let native = DecodedLayer::from_compressed(&compressed);
    let mut rng = f2f::rng::Rng::new(1);
    let xs: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..COLS).map(|_| rng.next_f32() - 0.5).collect())
        .collect();
    let ys = pjrt.run_padded(&xs)?;
    for (x, y) in xs.iter().zip(&ys) {
        let want = native.gemv(x);
        for (a, b) in y.iter().zip(&want) {
            assert!(
                (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                "PJRT {a} vs native {b}"
            );
        }
    }
    println!("PJRT decode+matvec matches native Rust decode (4 probes)");
    drop(pjrt); // PJRT handles are !Send — the worker builds its own.

    // --- serve: batched load test through the coordinator ---
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let layer_for_worker = compressed.clone();
    let server = InferenceServer::start(
        ServerConfig {
            max_batch: 32,
            batch_timeout: std::time::Duration::from_millis(2),
            ..Default::default()
        },
        move || {
            Box::new(
                PjrtBackend::load(&layer_for_worker)
                    .expect("worker backend init"),
            ) as Box<dyn Backend>
        },
    );
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = (0..requests)
        .map(|i| {
            let x: Vec<f32> =
                (0..COLS).map(|j| ((i * j) as f32 * 1e-3).sin()).collect();
            server.infer_async(x)
        })
        .collect();
    for p in pending {
        p.recv()??;
    }
    let dt = t0.elapsed();
    let m = server.metrics();
    println!(
        "served {requests} requests in {dt:?}: {:.0} req/s, batches={} (mean size {:.1})",
        requests as f64 / dt.as_secs_f64(),
        m.batches,
        m.mean_batch_size(),
    );
    println!(
        "latency p50={:?} p95={:?} p99={:?} max={:?}",
        m.p50, m.p95, m.p99, m.max
    );
    server.shutdown();
    println!("serve_compressed OK");
    Ok(())
}

//! END-TO-END DRIVER: compress a whole model, serve it under a budget.
//!
//! 1. A 3-layer synthetic INT8 MLP is compressed with the paper's
//!    sequential fixed-to-fixed scheme (Rust encoder) into the indexed
//!    container v2 (`F2F2`).
//! 2. A `ModelStore` opens the bytes with a decoded-weight budget
//!    *smaller than the decoded model*, so serving exercises
//!    decode-on-miss (parallel per-plane `DecodePool`) and evict-cold.
//! 3. A `ModelBackend` chains the layers (GEMV + ReLU) behind the
//!    batching `InferenceServer`; outputs are cross-checked against the
//!    serially-decoded native path (bit-exact weights ⇒ identical
//!    forward up to f32 accumulation order). The forward pass runs the
//!    readahead pipeline: layer `i+1` decodes on the persistent
//!    `DecodeService` while layer `i`'s GEMV runs, and the executing
//!    layer is pinned so readahead installs can never evict it.
//! 4. A cold-pass comparison times decode-on-miss (readahead off)
//!    against the overlapped pipeline and the cost-model `auto`
//!    planner (`bench_util::timed_pass` does the timing, the same
//!    primitive the benches use), then a load test reports throughput,
//!    latency percentiles, store cache metrics, and the per-layer
//!    observed cost table the planner reads.
//! 5. The same container is split across 2 shards (`ShardMap` +
//!    `ShardRouter`): the multi-store forward pass must be bit-exact
//!    vs the single store, with each shard decoding only its layers.
//! 6. (unix) Multi-process walkthrough: the same 2 shards served by
//!    IPC workers over unix-domain sockets behind an `ipc::ProcRouter`
//!    — the wire protocol, cross-process readahead, and worker-side
//!    metrics/cost aggregation, still bit-exact. In production the
//!    workers are separate supervised OS processes:
//!    `f2f serve --shard-procs 2`.
//!
//! With `--features pjrt` (requires the external `xla` bindings and
//! `make artifacts`), an additional single-layer cross-check runs the
//! AOT-compiled PJRT decode+matvec executable first.
//!
//! ```text
//! cargo run --release --example serve_compressed
//! ```

use anyhow::Result;
use f2f::bench_util::timed_pass;
use f2f::container::{
    write_container_v2, write_sharded, Container, ShardAssignment,
};
use f2f::coordinator::{InferenceServer, ServerConfig};
use f2f::models::{compressed_mlp, MlpConfig};
use f2f::shard::ShardRouter;
use f2f::sparse::DecodedLayer;
use f2f::store::{ModelBackend, ModelStore, ReadaheadPolicy, StoreConfig};
use std::sync::Arc;

/// Layer widths of the demo MLP: 512 → 256 → 256 → 128.
const DIMS: [usize; 4] = [512, 256, 256, 128];
const N_S: usize = 2;

fn compress_model() -> Container {
    let t0 = std::time::Instant::now();
    let (c, reports) = compressed_mlp(&MlpConfig {
        seed: 0x5E,
        n_s: N_S,
        name_prefix: "mlp/fc".into(),
        ..MlpConfig::new(&DIMS)
    });
    for (rep, l) in reports.iter().zip(&c.layers) {
        println!(
            "compressed {} ({}x{} INT8): E={:.2}% mem_reduction={:.2}%",
            rep.name, l.rows, l.cols, rep.efficiency, rep.memory_reduction
        );
    }
    println!("model compressed in {:?}", t0.elapsed());
    c
}

/// Reference forward pass from serially-decoded layers.
fn reference_forward(c: &Container, x: &[f32]) -> Vec<f32> {
    let mut a = x.to_vec();
    for (i, l) in c.layers.iter().enumerate() {
        let dec = DecodedLayer::from_compressed(l);
        let mut y = dec.gemv(&a);
        if i + 1 < c.layers.len() {
            for v in &mut y {
                *v = v.max(0.0);
            }
        }
        a = y;
    }
    a
}

fn main() -> Result<()> {
    #[cfg(feature = "pjrt")]
    pjrt_check::run()?;

    let model = compress_model();
    let bytes = write_container_v2(&model);
    println!(
        "container v2: {} bytes ({:.2}% total memory reduction)",
        bytes.len(),
        model.memory_reduction()
    );

    // --- cold-pass comparison: decode-on-miss vs readahead overlap
    // vs the cost-model auto planner (seeded from the previous pass's
    // observed costs, so it plans instead of falling back to depth 1).
    let probe: Vec<f32> =
        (0..DIMS[0]).map(|j| (j as f32 * 1e-2).sin()).collect();
    let mut cold = Vec::new();
    let mut outputs = Vec::new();
    let mut cost_snapshot = Vec::new();
    for policy in [
        ReadaheadPolicy::off(),
        ReadaheadPolicy::layers(1),
        ReadaheadPolicy::auto(),
    ] {
        let store = Arc::new(ModelStore::open_bytes(
            bytes.clone(),
            StoreConfig::default(),
        )?);
        store.seed_costs(cost_snapshot.iter().cloned());
        let mut backend = ModelBackend::sequential(store.clone())?
            .with_readahead(policy);
        let (ys, dt) = timed_pass(&mut backend, &[probe.clone()])?;
        cold.push(dt);
        outputs.push(ys);
        store.wait_for_idle();
        assert_eq!(store.metrics().redundant_decodes, 0);
        cost_snapshot = store.costs().snapshot();
    }
    assert_eq!(
        outputs[0], outputs[1],
        "readahead must never change outputs"
    );
    assert_eq!(
        outputs[0], outputs[2],
        "the auto planner must never change outputs"
    );
    println!(
        "cold pass: decode-on-miss {:?} vs readahead {:?} ({:.2}x) vs \
         auto-planned {:?} ({:.2}x)",
        cold[0],
        cold[1],
        cold[0].as_secs_f64() / cold[1].as_secs_f64().max(1e-9),
        cold[2],
        cold[0].as_secs_f64() / cold[2].as_secs_f64().max(1e-9),
    );

    // --- sharded: the same model behind 2 independent stores ---
    {
        use f2f::coordinator::Backend;
        let single_store = Arc::new(ModelStore::open_bytes(
            bytes.clone(),
            StoreConfig::default(),
        )?);
        let mut single = ModelBackend::sequential(single_store)?;
        let want = single.forward_batch(&[probe.clone()])?;

        let (map, shard_bytes) =
            write_sharded(&model, 2, ShardAssignment::ByBytes)?;
        let stores = shard_bytes
            .into_iter()
            .map(|b| {
                ModelStore::open_bytes(b, StoreConfig::default())
                    .map(Arc::new)
            })
            .collect::<Result<Vec<_>>>()?;
        for (i, s) in stores.iter().enumerate() {
            println!(
                "shard {i}: layers [{}], decoded {} KiB",
                map.layers_of(i).collect::<Vec<_>>().join(","),
                s.total_decoded_bytes() >> 10
            );
        }
        let mut router = ShardRouter::new(stores, &map)?
            .with_readahead(ReadaheadPolicy::layers(1));
        let (got, dt) = timed_pass(&mut router, &[probe.clone()])?;
        assert_eq!(
            got, want,
            "2-shard router must be bit-exact vs single store"
        );
        router.wait_for_idle();
        let sm = router.metrics();
        assert_eq!(sm.total.redundant_decodes, 0);
        println!(
            "2-shard cold pass {dt:?}: output bit-exact vs single store \
             (decodes per shard: {:?})",
            sm.per_shard.iter().map(|m| m.decodes).collect::<Vec<_>>()
        );
    }

    // --- multi-process serving walkthrough (unix) ---
    #[cfg(unix)]
    multiproc_walkthrough(&model, &bytes, &probe)?;

    // Budget below the decoded model size: eviction is guaranteed.
    let decoded_total: usize =
        model.layers.iter().map(|l| l.n_weights() * 4).sum();
    let budget = decoded_total * 2 / 3;
    let store = Arc::new(ModelStore::open_bytes(
        bytes,
        StoreConfig {
            cache_budget_bytes: budget,
            decode_workers: 0,
            ..StoreConfig::default()
        },
    )?);
    println!(
        "store: decoded model {} KiB, cache budget {} KiB, {} decode workers",
        decoded_total >> 10,
        budget >> 10,
        store.decode_workers()
    );

    // --- correctness: served output == serially decoded chain ---
    let backend = ModelBackend::sequential(store.clone())?
        .with_readahead(ReadaheadPolicy::layers(1));
    let server = InferenceServer::start(
        ServerConfig {
            max_batch: 32,
            batch_timeout: std::time::Duration::from_millis(2),
            ..Default::default()
        },
        move || Box::new(backend),
    );
    let mut rng = f2f::rng::Rng::new(1);
    for probe in 0..4 {
        let x: Vec<f32> =
            (0..DIMS[0]).map(|_| rng.next_f32() - 0.5).collect();
        let y = server.infer(x.clone())?;
        let want = reference_forward(&model, &x);
        assert_eq!(y.len(), *DIMS.last().unwrap());
        for (a, b) in y.iter().zip(&want) {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                "probe {probe}: served {a} vs native {b}"
            );
        }
    }
    println!("served outputs match native serial decode (4 probes)");

    // --- load test ---
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = (0..requests)
        .map(|i| {
            let x: Vec<f32> = (0..DIMS[0])
                .map(|j| ((i * j) as f32 * 1e-3).sin())
                .collect();
            server.infer_async(x)
        })
        .collect();
    for p in pending {
        p.recv()??;
    }
    let dt = t0.elapsed();
    let m = server.metrics();
    println!(
        "served {requests} requests in {dt:?}: {:.0} req/s, batches={} \
         (mean size {:.1})",
        requests as f64 / dt.as_secs_f64(),
        m.batches,
        m.mean_batch_size(),
    );
    println!(
        "latency p50={:?} p95={:?} p99={:?} max={:?}",
        m.p50, m.p95, m.p99, m.max
    );
    let sm = store.metrics();
    println!(
        "store: hits={} misses={} decodes={} evictions={} cached {} KiB \
         ({} layers)",
        sm.hits,
        sm.misses,
        sm.decodes,
        sm.evictions,
        sm.cached_bytes >> 10,
        sm.cached_layers
    );
    println!(
        "readahead: prefetches={} skips={} redundant_decodes={}",
        sm.prefetches, sm.readahead_skips, sm.redundant_decodes,
    );
    // The telemetry the auto planner (and `f2f rebalance`) consumes.
    for (name, c) in store.costs().snapshot() {
        println!(
            "cost[{name}]: decode {:.1}us ({} samples), gemv \
             {:.2}us/item ({} samples)",
            c.decode_ns / 1e3,
            c.decode_samples,
            c.gemv_ns / 1e3,
            c.gemv_samples,
        );
    }
    assert!(
        sm.decode_ns_total > 0 && sm.gemv_ns_total > 0,
        "serving must leave timing telemetry behind"
    );
    assert!(sm.evictions > 0, "budget below model size must evict");
    assert_eq!(
        sm.redundant_decodes, 0,
        "in-flight dedup: a get and a readahead never double-decode"
    );
    // The observability layer watched the whole run: a span per
    // queue/batch/decode/GEMV phase (export with `f2f serve
    // --trace-out`), mergeable histograms behind the percentiles
    // printed above (`--metrics-out` writes the full registry).
    let spans = f2f::obs::snapshot();
    if f2f::obs::enabled() {
        assert!(!spans.is_empty(), "serving must leave spans behind");
    }
    println!(
        "observability: {} spans recorded, {} request latencies in \
         the histogram (p99 {:?})",
        spans.len(),
        m.latency.count(),
        m.latency.percentile(0.99),
    );
    server.shutdown();
    println!("serve_compressed OK");
    Ok(())
}

/// Multi-process serving walkthrough: the same 2-shard split served
/// through the IPC tier. The workers here run as in-process threads
/// over real unix-domain sockets so the example stays a single
/// self-contained binary; everything else — the wire protocol, the
/// `ProcRouter`'s cross-process readahead, the worker-side metrics
/// and cost aggregation — is exactly the multi-process path. For real
/// deployments each worker is its own supervised OS process:
///
/// ```text
/// f2f serve --shard-procs 2            # spawn + route + supervise
/// f2f shard-worker shard0.f2f --socket /run/f2f/s0.sock   # one shard
/// ```
#[cfg(unix)]
fn multiproc_walkthrough(
    model: &Container,
    bytes: &[u8],
    probe: &[f32],
) -> Result<()> {
    use f2f::container::ContainerIndex;
    use f2f::coordinator::Backend;
    use f2f::ipc::{IpcShardStore, ProcRouter};

    println!("-- multi-process serving walkthrough --");
    let single_store = Arc::new(ModelStore::open_bytes(
        bytes.to_vec(),
        StoreConfig::default(),
    )?);
    let mut single = ModelBackend::sequential(single_store)?;
    let want = single.forward_batch(&[probe.to_vec()])?;

    // Split, then serve each shard from its own worker behind a
    // unix socket.
    let (map, shard_bytes) =
        write_sharded(model, 2, ShardAssignment::ByBytes)?;
    let mut clients = Vec::new();
    let mut workers = Vec::new();
    for (i, b) in shard_bytes.into_iter().enumerate() {
        let socket = std::env::temp_dir().join(format!(
            "f2f-example-ipc-{i}-{}.sock",
            std::process::id()
        ));
        let store = Arc::new(ModelStore::open_bytes(
            b,
            StoreConfig::default(),
        )?);
        let s = socket.clone();
        workers.push(std::thread::spawn(move || {
            f2f::ipc::serve_store(store, &s)
        }));
        println!(
            "worker {i}: layers [{}] on {}",
            map.layers_of(i).collect::<Vec<_>>().join(","),
            socket.display()
        );
        clients.push(Arc::new(IpcShardStore::connect(&socket)));
    }
    // Bounded readiness wait: a worker that failed to bind must
    // surface its error instead of hanging the walkthrough.
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(10);
    for (i, c) in clients.iter().enumerate() {
        while !c.ping() {
            if std::time::Instant::now() > deadline {
                anyhow::bail!(
                    "ipc walkthrough: worker {i} did not come up \
                     within 10s"
                );
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }

    // The router walks the chain over IPC; while layer i's GEMV runs
    // here, layer i+1 warms on its worker's decode service.
    let index = ContainerIndex::parse(bytes)?;
    let mut router = ProcRouter::new(clients.clone(), &map, &index)?
        .with_readahead(ReadaheadPolicy::layers(1));
    let (got, dt) = timed_pass(&mut router, &[probe.to_vec()])?;
    assert_eq!(
        got, want,
        "IPC-served outputs must be bit-exact vs the single store"
    );
    let m = router.metrics()?;
    println!(
        "IPC cold pass {dt:?}: bit-exact vs single store \
         (worker decodes: {:?}, redundant: {})",
        m.per_shard.iter().map(|s| s.decodes).collect::<Vec<_>>(),
        m.total.redundant_decodes,
    );
    let profile = router.cost_profile()?;
    println!(
        "wire-gathered cost profile covers {} layers (decode from \
         workers, gemv from the router) — `f2f serve --shard-procs 2 \
         --profile-out` writes it for `f2f rebalance`",
        profile.len()
    );
    for c in &clients {
        let _ = c.shutdown();
    }
    for w in workers {
        let _ = w.join();
    }
    println!("workers shut down cleanly over the wire");
    Ok(())
}

/// Single-layer PJRT cross-check (original end-to-end driver): the
/// AOT-compiled decode+matvec executable must match the native decode.
#[cfg(feature = "pjrt")]
mod pjrt_check {
    use anyhow::{bail, Context, Result};
    use f2f::container::CompressedLayer;
    use f2f::decoder::SequentialDecoder;
    use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
    use f2f::pipeline::{CompressionConfig, Compressor};
    use f2f::pruning::PruneMethod;
    use f2f::runtime::{Input, LoadedModel, Runtime};
    use f2f::sparse::DecodedLayer;
    use std::path::PathBuf;

    const ROWS: usize = 256;
    const COLS: usize = 512;
    const N_S: usize = 2;
    const N_OUT: usize = 80;

    fn artifacts_dir() -> PathBuf {
        std::env::var("F2F_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Marshalled PJRT inputs shared by every request (the weights).
    struct StaticInputs {
        encoded_bits: Vec<f32>, // [8, l+N_s, 8]
        m_t: Vec<f32>,          // [K, N_out]
        corr: Vec<f32>,         // [8, l*N_out]
        invert: Vec<f32>,       // [8]
        mask: Vec<f32>,         // [n]
        scale: f32,
        l: usize,
    }

    fn marshal(layer: &CompressedLayer) -> StaticInputs {
        let n = layer.n_weights();
        let spec = layer.spec;
        let l = spec.num_blocks(n);
        let k = spec.total_inputs();
        let stream = l + spec.n_s;

        let mut encoded_bits = vec![0f32; 8 * stream * spec.n_in];
        let mut corr = vec![0f32; 8 * l * spec.n_out];
        let mut invert = vec![0f32; 8];
        for (p, plane) in layer.planes.iter().enumerate() {
            assert_eq!(plane.encoded.len(), stream);
            for (t, &chunk) in plane.encoded.iter().enumerate() {
                for b in 0..spec.n_in {
                    encoded_bits[(p * stream + t) * spec.n_in + b] =
                        ((chunk >> b) & 1) as f32;
                }
            }
            for pos in plane.correction.positions() {
                corr[p * l * spec.n_out + pos] = 1.0;
            }
            invert[p] = plane.inverted as u8 as f32;
        }
        // m_t[j][i] = M[i][j] (transpose of the row-major decoder matrix).
        let dec = SequentialDecoder::random(spec, layer.m_seed);
        let mut m_t = vec![0f32; k * spec.n_out];
        for j in 0..k {
            for i in 0..spec.n_out {
                if dec.matrix().get(i, j) {
                    m_t[j * spec.n_out + i] = 1.0;
                }
            }
        }
        let mask: Vec<f32> =
            (0..n).map(|i| layer.mask.get(i) as u8 as f32).collect();
        StaticInputs {
            encoded_bits,
            m_t,
            corr,
            invert,
            mask,
            scale: layer.scale,
            l,
        }
    }

    fn run_one(
        model: &LoadedModel,
        si: &StaticInputs,
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let stream = (si.l + N_S) as i64;
        let outputs = model.run(&[
            Input::F32(&si.encoded_bits, &[8, stream, 8]),
            Input::F32(&si.m_t, &[((N_S + 1) * 8) as i64, N_OUT as i64]),
            Input::F32(&si.corr, &[8, (si.l * N_OUT) as i64]),
            Input::F32(&si.invert, &[8]),
            Input::F32(&si.mask, &[(ROWS * COLS) as i64]),
            Input::F32(x, &[1, COLS as i64]),
            Input::F32(std::slice::from_ref(&si.scale), &[]),
        ])?;
        Ok(outputs[0][..ROWS].to_vec())
    }

    pub fn run() -> Result<()> {
        let rt = Runtime::cpu()?;
        println!("PJRT platform: {}", rt.platform());
        let path = artifacts_dir().join("decode_matvec_b1.hlo.txt");
        if !path.exists() {
            bail!(
                "artifact {} missing — run `make artifacts` first",
                path.display()
            );
        }
        let model = rt.load_hlo_text(&path).context("load artifact")?;

        let spec =
            LayerSpec { name: "serve/fc".into(), rows: ROWS, cols: COLS };
        let layer =
            SyntheticLayer::generate(&spec, WeightGen::default(), 0x5E);
        let (q, scale) = quantize_i8(&layer.weights);
        let compressor = Compressor::new(CompressionConfig {
            sparsity: 0.9,
            n_s: N_S,
            method: PruneMethod::Magnitude,
            beam: Some(8),
            ..Default::default()
        });
        let (compressed, _) =
            compressor.compress_i8("serve/fc", ROWS, COLS, &q, scale);
        let si = marshal(&compressed);
        let native = DecodedLayer::from_compressed(&compressed);
        let mut rng = f2f::rng::Rng::new(1);
        for _ in 0..4 {
            let x: Vec<f32> =
                (0..COLS).map(|_| rng.next_f32() - 0.5).collect();
            let y = run_one(&model, &si, &x)?;
            let want = native.gemv(&x);
            for (a, b) in y.iter().zip(&want) {
                assert!(
                    (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                    "PJRT {a} vs native {b}"
                );
            }
        }
        println!("PJRT decode+matvec matches native Rust decode (4 probes)");
        Ok(())
    }
}

//! Compress a (synthetic) sparse Transformer — the paper's Table 2
//! Transformer/FP32 workload at laptop scale.
//!
//! Compresses a spread of attention/FFN layers at S = 0.9 with
//! magnitude pruning and the inverting technique, prints the per-layer
//! and aggregate E / memory-reduction, and verifies the container
//! round-trips losslessly.
//!
//! ```text
//! cargo run --release --example compress_transformer [weights_per_layer]
//! ```

use f2f::container::Dtype;
use f2f::models::{transformer_layers, SyntheticLayer, WeightGen};
use f2f::pipeline::{CompressionConfig, Compressor, LayerReport};
use f2f::pruning::PruneMethod;
use f2f::report::Table;
use f2f::sparse::DecodedLayer;

fn main() {
    let max_w: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096);

    let picks = [
        "enc0/self_att/q",
        "enc3/ffn1",
        "dec3/self_att/q",
        "dec3/ffn2",
        "dec5/enc_att/output",
    ];
    let all = transformer_layers();
    let layers: Vec<SyntheticLayer> = picks
        .iter()
        .map(|n| {
            let spec = all.iter().find(|l| &l.name == n).unwrap();
            SyntheticLayer::generate(spec, WeightGen::default(), 0xAAA)
                .truncated(max_w)
        })
        .collect();

    let cfg = CompressionConfig {
        sparsity: 0.9,
        n_s: 2,
        method: PruneMethod::Magnitude,
        invert: true,
        beam: Some(8),
        ..Default::default()
    };
    let compressor = Compressor::new(cfg);
    let t0 = std::time::Instant::now();
    let (container, reports) =
        compressor.compress_model(&layers, Dtype::F32);
    let dt = t0.elapsed();

    let mut table = Table::new(
        &format!("Transformer FP32, S=0.9, Mag., N_s=2 ({dt:?})"),
        &["layer", "weights", "E%", "mem_red%", "coeff_var"],
    );
    for r in &reports {
        table.row(vec![
            r.name.clone(),
            r.n_weights.to_string(),
            format!("{:.2}", r.efficiency),
            format!("{:.2}", r.memory_reduction),
            format!("{:.3}", r.coeff_var),
        ]);
    }
    let agg = LayerReport::aggregate("model", &reports);
    table.row(vec![
        "== aggregate ==".into(),
        agg.n_weights.to_string(),
        format!("{:.2}", agg.efficiency),
        format!("{:.2}", agg.memory_reduction),
        format!("{:.3}", agg.coeff_var),
    ]);
    print!("{}", table.render());

    // Lossless verification through the serialized container.
    let bytes = f2f::container::write_container(&container);
    println!("container: {} bytes", bytes.len());
    let back = f2f::container::read_container(&bytes).expect("parse");
    for (orig, layer) in layers.iter().zip(&back.layers) {
        let decoded = DecodedLayer::from_compressed(layer);
        for i in 0..orig.weights.len() {
            if layer.mask.get(i) {
                assert_eq!(
                    decoded.weights[i].to_bits(),
                    orig.weights[i].to_bits(),
                    "{}[{i}] corrupted",
                    layer.name
                );
            }
        }
    }
    println!("all unpruned FP32 weights bit-exact after container round-trip");
}

//! Compress a (synthetic) sparse Transformer — the paper's Table 2
//! Transformer/FP32 workload at laptop scale.
//!
//! Compresses a spread of attention/FFN layers at S = 0.9 with
//! magnitude pruning and the inverting technique, prints the per-layer
//! and aggregate E / memory-reduction, and verifies the container
//! round-trips losslessly.
//!
//! ```text
//! cargo run --release --example compress_transformer [weights_per_layer]
//! cargo run --release --example compress_transformer -- --serve
//! ```
//!
//! `--serve` switches to the zoo demo leg: compress the canonical
//! Transformer table *with kind records* (a v3 container carrying its
//! attention + FFN chain) next to a companion ResNet ladder, serve
//! both tenants from one shared-budget registry, and print per-model
//! observed cost tables.

use f2f::container::Dtype;
use f2f::coordinator::Backend;
use f2f::models::{
    resnet_chain, tiny_resnet_layers, tiny_transformer_layers,
    transformer_chain, transformer_layers, LayerSpec, SyntheticLayer,
    WeightGen,
};
use f2f::pipeline::{CompressionConfig, Compressor, LayerReport};
use f2f::pruning::PruneMethod;
use f2f::registry::{ModelRegistry, ZooModel};
use f2f::report::Table;
use f2f::sparse::DecodedLayer;
use f2f::store::{ReadaheadPolicy, StoreConfig};

fn main() {
    if std::env::args().any(|a| a == "--serve") {
        serve_zoo_demo();
        return;
    }
    let max_w: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096);

    let picks = [
        "enc0/self_att/q",
        "enc3/ffn1",
        "dec3/self_att/q",
        "dec3/ffn2",
        "dec5/enc_att/output",
    ];
    let all = transformer_layers();
    let layers: Vec<SyntheticLayer> = picks
        .iter()
        .map(|n| {
            let spec = all.iter().find(|l| &l.name == n).unwrap();
            SyntheticLayer::generate(spec, WeightGen::default(), 0xAAA)
                .truncated(max_w)
        })
        .collect();

    let cfg = CompressionConfig {
        sparsity: 0.9,
        n_s: 2,
        method: PruneMethod::Magnitude,
        invert: true,
        beam: Some(8),
        ..Default::default()
    };
    let compressor = Compressor::new(cfg);
    let t0 = std::time::Instant::now();
    let (container, reports) =
        compressor.compress_model(&layers, Dtype::F32);
    let dt = t0.elapsed();

    let mut table = Table::new(
        &format!("Transformer FP32, S=0.9, Mag., N_s=2 ({dt:?})"),
        &["layer", "weights", "E%", "mem_red%", "coeff_var"],
    );
    for r in &reports {
        table.row(vec![
            r.name.clone(),
            r.n_weights.to_string(),
            format!("{:.2}", r.efficiency),
            format!("{:.2}", r.memory_reduction),
            format!("{:.3}", r.coeff_var),
        ]);
    }
    let agg = LayerReport::aggregate("model", &reports);
    table.row(vec![
        "== aggregate ==".into(),
        agg.n_weights.to_string(),
        format!("{:.2}", agg.efficiency),
        format!("{:.2}", agg.memory_reduction),
        format!("{:.3}", agg.coeff_var),
    ]);
    print!("{}", table.render());

    // Lossless verification through the serialized container.
    let bytes = f2f::container::write_container(&container);
    println!("container: {} bytes", bytes.len());
    let back = f2f::container::read_container(&bytes).expect("parse");
    for (orig, layer) in layers.iter().zip(&back.layers) {
        let decoded = DecodedLayer::from_compressed(layer);
        for i in 0..orig.weights.len() {
            if layer.mask.get(i) {
                assert_eq!(
                    decoded.weights[i].to_bits(),
                    orig.weights[i].to_bits(),
                    "{}[{i}] corrupted",
                    layer.name
                );
            }
        }
    }
    println!("all unpruned FP32 weights bit-exact after container round-trip");
}

/// Compress one tenant's layer table with its chain into a v3
/// container and load it back as a zoo tenant — the chain rides *in*
/// the container, not beside it.
fn compress_tenant(
    id: &str,
    specs: &[LayerSpec],
    chain: f2f::container::ChainSpec,
    cfg: CompressionConfig,
) -> ZooModel {
    let layers: Vec<SyntheticLayer> = specs
        .iter()
        .map(|s| SyntheticLayer::generate(s, WeightGen::default(), 0xAAA))
        .collect();
    let (container, reports) =
        Compressor::new(cfg).compress_model(&layers, Dtype::I8);
    let agg = LayerReport::aggregate(id, &reports);
    println!(
        "{id}: {} layers compressed, E={:.2}% mem_reduction={:.2}%",
        specs.len(),
        agg.efficiency,
        agg.memory_reduction
    );
    let bytes = f2f::container::write_container_v3(&container, &[chain]);
    ZooModel::from_bytes(id, &bytes).expect("v3 container round-trip")
}

/// The `--serve` demo: a Transformer (attention + FFN kind records)
/// and a ResNet ladder (conv-as-GEMM + downsample residuals) served
/// concurrently from one registry under a shared byte budget small
/// enough that a burst on one tenant evicts the other's cold layers.
fn serve_zoo_demo() {
    let cfg = CompressionConfig {
        sparsity: 0.9,
        n_s: 1,
        method: PruneMethod::Magnitude,
        beam: Some(8),
        ..Default::default()
    };
    let tx_specs = tiny_transformer_layers(2, 64, 256);
    let tx_chain =
        transformer_chain("transformer", &tx_specs).expect("chain");
    let rn_specs = tiny_resnet_layers(&[(8, 32), (16, 64)]);
    let rn_chain = resnet_chain("resnet50", &rn_specs).expect("chain");
    let decoded_bytes: usize = tx_specs
        .iter()
        .chain(&rn_specs)
        .map(|s| s.n_weights() * 4)
        .sum();

    let zoo = vec![
        compress_tenant("transformer", &tx_specs, tx_chain, cfg),
        compress_tenant("resnet50", &rn_specs, rn_chain, cfg),
    ];

    // A budget below the combined decoded size: serving one tenant
    // must push the other's cold layers out, never a pinned one.
    let budget = decoded_bytes * 6 / 10;
    let mut registry = ModelRegistry::new(
        &zoo,
        StoreConfig {
            cache_budget_bytes: budget,
            ..Default::default()
        },
    )
    .expect("registry")
    .with_readahead(ReadaheadPolicy::layers(1));
    println!(
        "zoo: {} models, combined decoded ~{} KiB, shared budget {} KiB",
        registry.n_models(),
        decoded_bytes >> 10,
        budget >> 10
    );

    for round in 0..3usize {
        for id in ["transformer", "resnet50"] {
            let dim = registry.chain(id).expect("chain").input_dim();
            let xs: Vec<Vec<f32>> = (0..4usize)
                .map(|i| {
                    (0..dim)
                        .map(|j| {
                            (((i * dim + j + round) as f32) * 0.37).sin()
                        })
                        .collect()
                })
                .collect();
            let ys = registry
                .forward_model_batch(id, &xs)
                .expect("zoo forward");
            assert!(
                ys.iter().flatten().all(|v| v.is_finite()),
                "{id}: non-finite output"
            );
        }
    }
    registry.wait_for_idle();

    if let Some(m) = registry.store_metrics() {
        println!(
            "shared store: decodes={} hits={} evictions={} \
             redundant_decodes={}",
            m.decodes, m.hits, m.evictions, m.redundant_decodes
        );
    }
    for id in registry.model_ids() {
        let mut table = Table::new(
            &format!("{id}: per-layer observed costs"),
            &["layer", "gemv_us_per_item", "samples"],
        );
        for (name, c) in registry.model_costs(&id) {
            table.row(vec![
                name,
                format!("{:.2}", c.gemv_ns / 1e3),
                c.gemv_samples.to_string(),
            ]);
        }
        print!("{}", table.render());
    }
}

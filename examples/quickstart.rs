//! Quickstart: compress one layer, verify losslessness, print stats.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
use f2f::pipeline::{CompressionConfig, Compressor};
use f2f::pruning::PruneMethod;
use f2f::sparse::DecodedLayer;

fn main() {
    // 1. A layer to compress: synthetic 64×512 Gaussian weights, INT8.
    let spec = LayerSpec { name: "demo/fc".into(), rows: 64, cols: 512 };
    let layer = SyntheticLayer::generate(&spec, WeightGen::default(), 42);
    let (q, scale) = quantize_i8(&layer.weights);

    // 2. Configure the paper's flagship scheme: N_in = 8, S = 0.9
    //    (→ N_out = 80), N_s = 2 sequential decoding.
    let cfg = CompressionConfig {
        sparsity: 0.9,
        n_s: 2,
        method: PruneMethod::Magnitude,
        beam: Some(16), // beam-pruned DP; drop for exact encoding
        ..Default::default()
    };
    println!("decoder spec: {:?}", cfg.decoder_spec());

    // 3. Compress.
    let compressor = Compressor::new(cfg);
    let t = std::time::Instant::now();
    let (compressed, report) =
        compressor.compress_i8("demo/fc", 64, 512, &q, scale);
    println!(
        "compressed in {:?}: E = {:.2}%  memory reduction = {:.2}% (max = S = 90%)",
        t.elapsed(),
        report.efficiency,
        report.memory_reduction,
    );

    // 4. Decode and verify losslessness on every unpruned weight.
    let decoded = DecodedLayer::from_compressed(&compressed);
    let mut checked = 0;
    for i in 0..q.len() {
        if compressed.mask.get(i) {
            assert_eq!(
                decoded.weights[i],
                q[i] as f32 * scale,
                "weight {i} corrupted!"
            );
            checked += 1;
        }
    }
    println!("lossless: {checked} unpruned weights bit-exact after decode");

    // 5. Algorithm 2: serve a mat-vec from the compressed form.
    let x: Vec<f32> = (0..512).map(|i| (i as f32 * 0.01).sin()).collect();
    let y = f2f::sparse::decode_gemv(&compressed, &x);
    println!("y[0..4] = {:?}", &y[..4]);

    // 6. Appendix G hardware cost of the decoder this layer ships with.
    let dec = f2f::decoder::SequentialDecoder::random(
        compressed.spec,
        compressed.m_seed,
    );
    let hw = dec.hardware_cost();
    println!(
        "decoder hardware: {} XOR gates ({} transistors), latency {} cycles, {} bits/cycle",
        hw.xor_gates, hw.transistors, hw.latency_cycles,
        hw.throughput_bits_per_cycle
    );
}
